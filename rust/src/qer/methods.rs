//! The unified QER method dispatcher: every baseline + SRR behind one
//! call, so the coordinator and the experiment benches treat methods
//! uniformly (paper Tables 1, 5, 16; Figure 7).
//!
//! Two entry points:
//!
//! * [`reconstruct`] — self-contained: derives the spectra it needs from
//!   `cfg.seed` and runs one config. What `run_ptq` calls per layer.
//! * [`reconstruct_prepared`] — shared-work: takes the (scaling, spectra)
//!   a [`PreparedSpectra`] cache computed once per layer and only runs
//!   the config-specific stages (quantize + residual SVD). What the
//!   sweep engine calls for every config of a grid.
//!
//! Both paths are bit-identical for the same `(cfg.seed, prep_rank)`:
//! the spectra RNG stream is salted and separate from the residual
//! stream, and every truncation is a prefix of the same prep-rank
//! factorization (see `QerConfig::prep_rank`).

use std::sync::Arc;

use crate::linalg::{randomized_svd, truncated_from, Svd};
use crate::quant::{PackedMat, QuantCtx, Quantizer};
use crate::scaling::{Scaling, ScalingKind};
use crate::serve::{LinearOp, QuantBase};
use crate::tensor::{matmul, Mat};
use crate::util::Rng;

use super::rank_select::{PreparedSpectra, RankSelection};
use super::srr::{srr_single_svd_prepared, srr_with_k_prepared, SrrOutput};

/// Which reconstruction pipeline to run.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Method {
    /// Quantization only, no low-rank correction.
    WOnly,
    /// Residual-only QER in the space chosen by `scaling` (k = 0).
    /// Covers ZeroQuant-V2 (identity), LQER (diag-rms), QERA-approx
    /// (diag-absmean) and QERA-exact (exact) depending on the scaling.
    Qer,
    /// `Qer` wrapped with SRR's rank allocation (k = k*).
    QerSrr,
    /// LoftQ / LQ-LoRA style iterative refinement: alternate
    /// LR ← SVD_r(S(W−Q)), Q ← quant(W − LR) for `iters` rounds (k ≈ r).
    IterativeLowRank { iters: usize },
    /// SVDQuant-style one-shot preserve-only: k = r, no reconstruction.
    PreserveOnly,
    /// ODLRI-like fixed split k = r/2 (extraction-first heuristic).
    FixedSplitHalf,
    /// SRR with the Eq. (6) single-SVD packing.
    SrrSingleSvd,
}

impl Method {
    pub fn label(&self) -> String {
        match self {
            Method::WOnly => "w-only".into(),
            Method::Qer => "QER".into(),
            Method::QerSrr => "QER+SRR".into(),
            Method::IterativeLowRank { iters } => format!("iterLR({iters})"),
            Method::PreserveOnly => "preserve-only".into(),
            Method::FixedSplitHalf => "fixed-k/2".into(),
            Method::SrrSingleSvd => "SRR(eq6)".into(),
        }
    }

    /// Whether this method consumes the prepared (SW, SE) spectra — the
    /// SRR family does; plain residual QER and w-only do not.
    pub fn needs_spectra(&self) -> bool {
        matches!(
            self,
            Method::QerSrr | Method::SrrSingleSvd | Method::PreserveOnly | Method::FixedSplitHalf
        )
    }
}

#[derive(Clone, Debug)]
pub struct QerConfig {
    pub method: Method,
    pub rank: usize,
    pub scaling_kind: ScalingKind,
    /// randomized-SVD power iterations (paper §A.4: 4)
    pub n_iter: usize,
    pub seed: u64,
    /// Rank all shared factorizations (spectra, residual SVDs) are
    /// computed at before prefix-truncating to `rank`. `None` means
    /// `rank` (the self-contained default). A sweep sets this to the
    /// grid's maximum rank on every config so its cached factorizations
    /// serve all budgets bit-identically.
    pub prep_rank: Option<usize>,
}

impl QerConfig {
    pub fn new(method: Method, rank: usize, scaling_kind: ScalingKind) -> Self {
        QerConfig { method, rank, scaling_kind, n_iter: 4, seed: 0, prep_rank: None }
    }

    /// Effective preparation rank (≥ `rank`).
    pub fn prep_rank(&self) -> usize {
        self.prep_rank.unwrap_or(self.rank).max(self.rank)
    }
}

/// Salt for the residual-stage RNG stream (kept distinct from the
/// spectra stream so prepared handoffs don't shift the draws).
pub(crate) const RESID_SALT: u64 = 0xD1CE_BA5E;

/// Result of reconstructing one weight matrix.
#[derive(Clone, Debug)]
pub struct QerResult {
    pub qdeq: Mat,
    /// bit-packed encoding of `qdeq` (None for quantizers without one);
    /// `into_factored` carries it into the serving layer. Behind an
    /// [`Arc`] so sweep outcomes that reuse a cached k=0 quantization
    /// share one buffer (and the fleet evaluator can group them by
    /// pointer identity).
    pub packed: Option<Arc<PackedMat>>,
    pub l: Mat,
    pub r: Mat,
    pub k_star: usize,
    pub selection: Option<RankSelection>,
}

impl QerResult {
    pub fn reconstruct(&self) -> Mat {
        if self.l.cols == 0 {
            self.qdeq.clone()
        } else {
            self.qdeq.add(&matmul(&self.l, &self.r))
        }
    }

    /// Consume into the factored serving representation: the quantized
    /// base stays bit-packed (dense only for quantizers without a packed
    /// format) and `W_hat` is never materialized.
    pub fn into_factored(self) -> LinearOp {
        let base = match self.packed {
            Some(p) => QuantBase::Packed(p),
            None => QuantBase::Dense(Arc::new(self.qdeq)),
        };
        LinearOp::FactoredQlr { base, l: self.l, r: self.r }
    }

    pub fn weight_error(&self, w: &Mat) -> f64 {
        w.sub(&self.reconstruct()).frob()
    }

    pub fn scaled_error(&self, w: &Mat, scaling: &Scaling) -> f64 {
        scaling.apply(&w.sub(&self.reconstruct())).frob()
    }

    fn from_srr(out: SrrOutput) -> QerResult {
        QerResult {
            qdeq: out.qdeq,
            packed: out.packed.map(Arc::new),
            l: out.l,
            r: out.r,
            k_star: out.k_star,
            selection: Some(out.selection),
        }
    }
}

/// Rank-`rank` correction factors from an (over-)computed residual SVD:
/// prefix-truncate, then pull the left factor back through S⁻¹. Exposed
/// so the sweep engine can serve several ranks from one factorization.
pub fn correction_from_svd(svd: &Svd, scaling: &Scaling, rank: usize) -> (Mat, Mat) {
    let (lu, rv) = truncated_from(svd, rank);
    (scaling.unapply(&lu), rv)
}

/// Residual-only correction: LR = S⁻¹ SVD_r(S(W − Q)), with the SVD
/// computed at `prep_rank` and truncated to `rank`.
fn residual_correction(
    w: &Mat,
    qdeq: &Mat,
    scaling: &Scaling,
    rank: usize,
    prep_rank: usize,
    n_iter: usize,
    rng: &mut Rng,
) -> (Mat, Mat) {
    let resid = scaling.apply(&w.sub(qdeq));
    let svd = randomized_svd(&resid, prep_rank, n_iter, rng);
    correction_from_svd(&svd, scaling, rank)
}

/// Run `cfg.method` on one weight matrix, deriving spectra on the fly.
///
/// `scaling` must already be built for this layer's calibration
/// activations (the coordinator owns that); `ctx` carries the Hessian /
/// seed for GPTQ / QuIP#.
pub fn reconstruct(
    w: &Mat,
    quantizer: &dyn Quantizer,
    scaling: &Scaling,
    ctx: &QuantCtx,
    cfg: &QerConfig,
) -> QerResult {
    let spectra = if cfg.method.needs_spectra() {
        Some(PreparedSpectra::compute(w, scaling, cfg.prep_rank(), cfg.n_iter, cfg.seed))
    } else {
        None
    };
    reconstruct_prepared(w, quantizer, scaling, spectra.as_ref(), ctx, cfg)
}

/// Run `cfg.method` against precomputed spectra.
///
/// `spectra` is consumed only by the SRR family; it must be prepared at
/// exactly `cfg.prep_rank()` and carry `cfg.seed`'s probe — a stale or
/// missing handoff falls back to recomputing locally (identical output,
/// no sharing).
pub fn reconstruct_prepared(
    w: &Mat,
    quantizer: &dyn Quantizer,
    scaling: &Scaling,
    spectra: Option<&PreparedSpectra>,
    ctx: &QuantCtx,
    cfg: &QerConfig,
) -> QerResult {
    let mut rng = Rng::new(cfg.seed ^ RESID_SALT);
    let (m, n) = (w.rows, w.cols);

    // resolve the spectra handoff for methods that need it; the rank
    // must match cfg.prep_rank() exactly — a randomized SVD sketched at
    // a different rank is a different factorization, and prefix
    // truncation only preserves bit-identity within one factorization
    let owned;
    let sp: Option<&PreparedSpectra> = if cfg.method.needs_spectra() {
        match spectra {
            Some(s) if s.rank == cfg.prep_rank() && s.seed == cfg.seed => Some(s),
            _ => {
                owned =
                    PreparedSpectra::compute(w, scaling, cfg.prep_rank(), cfg.n_iter, cfg.seed);
                Some(&owned)
            }
        }
    } else {
        None
    };

    match cfg.method {
        Method::WOnly => {
            let (qdeq, packed) = quantizer.quantize_coded(w, ctx);
            QerResult {
                qdeq,
                packed: packed.map(Arc::new),
                l: Mat::zeros(m, 0),
                r: Mat::zeros(0, n),
                k_star: 0,
                selection: None,
            }
        }
        Method::Qer => {
            let (qdeq, packed) = quantizer.quantize_coded(w, ctx);
            let (l, r) = residual_correction(
                w, &qdeq, scaling, cfg.rank, cfg.prep_rank(), cfg.n_iter, &mut rng,
            );
            QerResult { qdeq, packed: packed.map(Arc::new), l, r, k_star: 0, selection: None }
        }
        Method::QerSrr => {
            let sp = sp.expect("spectra resolved above");
            let sel = sp.select(cfg.rank);
            let k = sel.k_star;
            QerResult::from_srr(srr_with_k_prepared(
                w, quantizer, scaling, sp, ctx, cfg.rank, k, cfg.n_iter, &mut rng, sel,
            ))
        }
        Method::SrrSingleSvd => {
            let sp = sp.expect("spectra resolved above");
            QerResult::from_srr(srr_single_svd_prepared(
                w, quantizer, scaling, sp, ctx, cfg.rank, cfg.n_iter, &mut rng,
            ))
        }
        Method::IterativeLowRank { iters } => {
            // LoftQ/LQ-LoRA: Q0 = quant(W); then alternate.
            let (mut qdeq, mut packed) = quantizer.quantize_coded(w, ctx);
            let mut lr_pair = residual_correction(
                w, &qdeq, scaling, cfg.rank, cfg.prep_rank(), cfg.n_iter, &mut rng,
            );
            for _ in 1..iters.max(1) {
                let lr = matmul(&lr_pair.0, &lr_pair.1);
                (qdeq, packed) = quantizer.quantize_coded(&w.sub(&lr), ctx);
                lr_pair = residual_correction(
                    w, &qdeq, scaling, cfg.rank, cfg.prep_rank(), cfg.n_iter, &mut rng,
                );
            }
            QerResult {
                qdeq,
                packed: packed.map(Arc::new),
                l: lr_pair.0,
                r: lr_pair.1,
                k_star: cfg.rank,
                selection: None,
            }
        }
        Method::PreserveOnly => {
            let sp = sp.expect("spectra resolved above");
            let sel = sp.select(cfg.rank);
            QerResult::from_srr(srr_with_k_prepared(
                w, quantizer, scaling, sp, ctx, cfg.rank, cfg.rank, cfg.n_iter, &mut rng, sel,
            ))
        }
        Method::FixedSplitHalf => {
            let sp = sp.expect("spectra resolved above");
            let sel = sp.select(cfg.rank);
            QerResult::from_srr(srr_with_k_prepared(
                w, quantizer, scaling, sp, ctx, cfg.rank, cfg.rank / 2, cfg.n_iter, &mut rng, sel,
            ))
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::MxintQuantizer;
    use crate::scaling::Scaling;
    use crate::util::Rng;

    fn aniso(m: usize, n: usize, decay: f32, rng: &mut Rng) -> Mat {
        let (qu, _) = crate::linalg::qr_thin(&Mat::randn(m, m.min(n), 1.0, rng));
        let (qv, _) = crate::linalg::qr_thin(&Mat::randn(n, m.min(n), 1.0, rng));
        let mut core = Mat::zeros(m.min(n), m.min(n));
        for i in 0..m.min(n) {
            *core.at_mut(i, i) = 8.0 / (1.0 + i as f32).powf(decay);
        }
        matmul(&matmul(&qu, &core), &qv.transpose())
    }

    fn run(method: Method, w: &Mat, rank: usize) -> QerResult {
        let q = MxintQuantizer::new(3, 32);
        let cfg = QerConfig::new(method, rank, ScalingKind::Identity);
        reconstruct(w, &q, &Scaling::Identity, &QuantCtx::default(), &cfg)
    }

    const ALL_CORRECTING: [Method; 6] = [
        Method::Qer,
        Method::QerSrr,
        Method::SrrSingleSvd,
        Method::IterativeLowRank { iters: 5 },
        Method::PreserveOnly,
        Method::FixedSplitHalf,
    ];

    #[test]
    fn every_method_beats_or_matches_wonly() {
        let mut rng = Rng::new(400);
        let w = aniso(64, 96, 1.0, &mut rng);
        let base = run(Method::WOnly, &w, 16).weight_error(&w);
        for method in ALL_CORRECTING {
            let err = run(method, &w, 16).weight_error(&w);
            assert!(err <= base * 1.001, "{}: {err} > w-only {base}", method.label());
        }
    }

    #[test]
    fn rank_budget_is_respected_by_all_methods() {
        let mut rng = Rng::new(401);
        let w = aniso(48, 64, 0.9, &mut rng);
        for method in [
            Method::Qer,
            Method::QerSrr,
            Method::SrrSingleSvd,
            Method::IterativeLowRank { iters: 3 },
            Method::PreserveOnly,
            Method::FixedSplitHalf,
        ] {
            let res = run(method, &w, 12);
            assert!(res.l.cols <= 12, "{} rank overflow", method.label());
            assert_eq!(res.l.cols, res.r.rows);
        }
    }

    #[test]
    fn srr_no_worse_than_qer_same_budget() {
        let mut rng = Rng::new(402);
        let w = aniso(96, 96, 1.3, &mut rng);
        let qer = run(Method::Qer, &w, 24).weight_error(&w);
        let srr = run(Method::QerSrr, &w, 24).weight_error(&w);
        assert!(srr <= qer * 1.02, "srr {srr} vs qer {qer}");
    }

    #[test]
    fn iterative_improves_over_single_shot_qer_at_low_bits() {
        let mut rng = Rng::new(403);
        let w = aniso(64, 64, 1.2, &mut rng);
        let q = MxintQuantizer::new(2, 32);
        let ctx = QuantCtx::default();
        let one = reconstruct(
            &w, &q, &Scaling::Identity, &ctx,
            &QerConfig::new(Method::Qer, 16, ScalingKind::Identity),
        );
        let it = reconstruct(
            &w, &q, &Scaling::Identity, &ctx,
            &QerConfig::new(Method::IterativeLowRank { iters: 5 }, 16, ScalingKind::Identity),
        );
        assert!(it.weight_error(&w) <= one.weight_error(&w) * 1.05);
    }

    #[test]
    fn selection_metadata_present_only_for_srr_family() {
        let mut rng = Rng::new(404);
        let w = aniso(32, 64, 1.0, &mut rng);
        assert!(run(Method::Qer, &w, 8).selection.is_none());
        let srr = run(Method::QerSrr, &w, 8);
        assert!(srr.selection.is_some());
        assert_eq!(srr.selection.as_ref().unwrap().k_star, srr.k_star);
    }

    #[test]
    fn prepared_handoff_is_bit_identical_to_self_contained() {
        // the sweep contract: precomputed spectra at prep rank + the same
        // (seed, prep_rank) config must reproduce `reconstruct` exactly
        let mut rng = Rng::new(405);
        let w = aniso(64, 64, 1.1, &mut rng);
        let q = MxintQuantizer::new(3, 32);
        let ctx = QuantCtx::default();
        for method in ALL_CORRECTING {
            for rank in [4usize, 8] {
                let mut cfg = QerConfig::new(method, rank, ScalingKind::Identity);
                cfg.seed = 17;
                cfg.prep_rank = Some(8);
                let solo = reconstruct(&w, &q, &Scaling::Identity, &ctx, &cfg);
                let spectra =
                    PreparedSpectra::compute(&w, &Scaling::Identity, 8, cfg.n_iter, cfg.seed);
                let shared = reconstruct_prepared(
                    &w, &q, &Scaling::Identity, Some(&spectra), &ctx, &cfg,
                );
                assert_eq!(solo.qdeq, shared.qdeq, "{} r={rank} qdeq", method.label());
                assert_eq!(solo.l, shared.l, "{} r={rank} L", method.label());
                assert_eq!(solo.r, shared.r, "{} r={rank} R", method.label());
                assert_eq!(solo.k_star, shared.k_star);
            }
        }
    }

    #[test]
    fn stale_spectra_handoff_falls_back_to_local_compute() {
        // wrong seed / insufficient rank must not be silently consumed
        let mut rng = Rng::new(406);
        let w = aniso(48, 64, 1.0, &mut rng);
        let q = MxintQuantizer::new(3, 32);
        let ctx = QuantCtx::default();
        let mut cfg = QerConfig::new(Method::QerSrr, 8, ScalingKind::Identity);
        cfg.seed = 5;
        let want = reconstruct(&w, &q, &Scaling::Identity, &ctx, &cfg);
        // stale seed
        let stale = PreparedSpectra::compute(&w, &Scaling::Identity, 8, cfg.n_iter, 99);
        let got = reconstruct_prepared(&w, &q, &Scaling::Identity, Some(&stale), &ctx, &cfg);
        assert_eq!(want.qdeq, got.qdeq);
        assert_eq!(want.l, got.l);
        // insufficient rank
        let small = PreparedSpectra::compute(&w, &Scaling::Identity, 4, cfg.n_iter, cfg.seed);
        let got2 = reconstruct_prepared(&w, &q, &Scaling::Identity, Some(&small), &ctx, &cfg);
        assert_eq!(want.qdeq, got2.qdeq);
        assert_eq!(want.l, got2.l);
    }

    #[test]
    fn prep_rank_defaults_to_rank() {
        let cfg = QerConfig::new(Method::Qer, 8, ScalingKind::Identity);
        assert_eq!(cfg.prep_rank(), 8);
        let mut wide = cfg.clone();
        wide.prep_rank = Some(16);
        assert_eq!(wide.prep_rank(), 16);
        // prep rank never shrinks below the budget
        let mut bad = cfg.clone();
        bad.prep_rank = Some(2);
        assert_eq!(bad.prep_rank(), 8);
    }
}
