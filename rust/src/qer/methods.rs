//! The unified QER method dispatcher: every baseline + SRR behind one
//! call, so the coordinator and the experiment benches treat methods
//! uniformly (paper Tables 1, 5, 16; Figure 7).

use crate::linalg::{randomized_svd, truncated_from};
use crate::quant::{QuantCtx, Quantizer};
use crate::scaling::{Scaling, ScalingKind};
use crate::tensor::{matmul, Mat};
use crate::util::Rng;

use super::rank_select::RankSelection;
use super::srr::{srr_decompose, srr_with_k, SrrOutput};

/// Which reconstruction pipeline to run.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Method {
    /// Quantization only, no low-rank correction.
    WOnly,
    /// Residual-only QER in the space chosen by `scaling` (k = 0).
    /// Covers ZeroQuant-V2 (identity), LQER (diag-rms), QERA-approx
    /// (diag-absmean) and QERA-exact (exact) depending on the scaling.
    Qer,
    /// `Qer` wrapped with SRR's rank allocation (k = k*).
    QerSrr,
    /// LoftQ / LQ-LoRA style iterative refinement: alternate
    /// LR ← SVD_r(S(W−Q)), Q ← quant(W − LR) for `iters` rounds (k ≈ r).
    IterativeLowRank { iters: usize },
    /// SVDQuant-style one-shot preserve-only: k = r, no reconstruction.
    PreserveOnly,
    /// ODLRI-like fixed split k = r/2 (extraction-first heuristic).
    FixedSplitHalf,
    /// SRR with the Eq. (6) single-SVD packing.
    SrrSingleSvd,
}

impl Method {
    pub fn label(&self) -> String {
        match self {
            Method::WOnly => "w-only".into(),
            Method::Qer => "QER".into(),
            Method::QerSrr => "QER+SRR".into(),
            Method::IterativeLowRank { iters } => format!("iterLR({iters})"),
            Method::PreserveOnly => "preserve-only".into(),
            Method::FixedSplitHalf => "fixed-k/2".into(),
            Method::SrrSingleSvd => "SRR(eq6)".into(),
        }
    }
}

#[derive(Clone, Debug)]
pub struct QerConfig {
    pub method: Method,
    pub rank: usize,
    pub scaling_kind: ScalingKind,
    /// randomized-SVD power iterations (paper §A.4: 4)
    pub n_iter: usize,
    pub seed: u64,
}

impl QerConfig {
    pub fn new(method: Method, rank: usize, scaling_kind: ScalingKind) -> Self {
        QerConfig { method, rank, scaling_kind, n_iter: 4, seed: 0 }
    }
}

/// Result of reconstructing one weight matrix.
#[derive(Clone, Debug)]
pub struct QerResult {
    pub qdeq: Mat,
    pub l: Mat,
    pub r: Mat,
    pub k_star: usize,
    pub selection: Option<RankSelection>,
}

impl QerResult {
    pub fn reconstruct(&self) -> Mat {
        if self.l.cols == 0 {
            self.qdeq.clone()
        } else {
            self.qdeq.add(&matmul(&self.l, &self.r))
        }
    }

    pub fn weight_error(&self, w: &Mat) -> f64 {
        w.sub(&self.reconstruct()).frob()
    }

    pub fn scaled_error(&self, w: &Mat, scaling: &Scaling) -> f64 {
        scaling.apply(&w.sub(&self.reconstruct())).frob()
    }

    fn from_srr(out: SrrOutput) -> QerResult {
        QerResult {
            qdeq: out.qdeq,
            l: out.l,
            r: out.r,
            k_star: out.k_star,
            selection: Some(out.selection),
        }
    }
}

/// Residual-only correction: LR = S⁻¹ SVD_r(S(W − Q)).
fn residual_correction(
    w: &Mat,
    qdeq: &Mat,
    scaling: &Scaling,
    rank: usize,
    n_iter: usize,
    rng: &mut Rng,
) -> (Mat, Mat) {
    let resid = scaling.apply(&w.sub(qdeq));
    let svd = randomized_svd(&resid, rank, n_iter, rng);
    let (lu, rv) = truncated_from(&svd, rank);
    (scaling.unapply(&lu), rv)
}

/// Run `cfg.method` on one weight matrix.
///
/// `scaling` must already be built for this layer's calibration
/// activations (the coordinator owns that); `ctx` carries the Hessian /
/// seed for GPTQ / QuIP#.
pub fn reconstruct(
    w: &Mat,
    quantizer: &dyn Quantizer,
    scaling: &Scaling,
    ctx: &QuantCtx,
    cfg: &QerConfig,
) -> QerResult {
    let mut rng = Rng::new(cfg.seed ^ 0xD1CE_BA5E);
    let (m, n) = (w.rows, w.cols);
    match cfg.method {
        Method::WOnly => QerResult {
            qdeq: quantizer.quantize(w, ctx),
            l: Mat::zeros(m, 0),
            r: Mat::zeros(0, n),
            k_star: 0,
            selection: None,
        },
        Method::Qer => {
            let qdeq = quantizer.quantize(w, ctx);
            let (l, r) = residual_correction(w, &qdeq, scaling, cfg.rank, cfg.n_iter, &mut rng);
            QerResult { qdeq, l, r, k_star: 0, selection: None }
        }
        Method::QerSrr => QerResult::from_srr(srr_decompose(
            w, quantizer, scaling, ctx, cfg.rank, cfg.n_iter, &mut rng,
        )),
        Method::SrrSingleSvd => QerResult::from_srr(super::srr::srr_single_svd(
            w, quantizer, scaling, ctx, cfg.rank, cfg.n_iter, &mut rng,
        )),
        Method::IterativeLowRank { iters } => {
            // LoftQ/LQ-LoRA: Q0 = quant(W); then alternate.
            let mut qdeq = quantizer.quantize(w, ctx);
            let mut lr_pair =
                residual_correction(w, &qdeq, scaling, cfg.rank, cfg.n_iter, &mut rng);
            for _ in 1..iters.max(1) {
                let lr = matmul(&lr_pair.0, &lr_pair.1);
                qdeq = quantizer.quantize(&w.sub(&lr), ctx);
                lr_pair =
                    residual_correction(w, &qdeq, scaling, cfg.rank, cfg.n_iter, &mut rng);
            }
            QerResult { qdeq, l: lr_pair.0, r: lr_pair.1, k_star: cfg.rank, selection: None }
        }
        Method::PreserveOnly => {
            let sel = super::rank_select::select_k(w, scaling, cfg.rank, cfg.n_iter, &mut rng);
            let out = srr_with_k(
                w, quantizer, scaling, ctx, cfg.rank, cfg.rank, cfg.n_iter, &mut rng, sel,
            );
            QerResult::from_srr(out)
        }
        Method::FixedSplitHalf => {
            let sel = super::rank_select::select_k(w, scaling, cfg.rank, cfg.n_iter, &mut rng);
            let out = srr_with_k(
                w, quantizer, scaling, ctx, cfg.rank, cfg.rank / 2, cfg.n_iter, &mut rng, sel,
            );
            QerResult::from_srr(out)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::MxintQuantizer;
    use crate::scaling::Scaling;
    use crate::util::Rng;

    fn aniso(m: usize, n: usize, decay: f32, rng: &mut Rng) -> Mat {
        let (qu, _) = crate::linalg::qr_thin(&Mat::randn(m, m.min(n), 1.0, rng));
        let (qv, _) = crate::linalg::qr_thin(&Mat::randn(n, m.min(n), 1.0, rng));
        let mut core = Mat::zeros(m.min(n), m.min(n));
        for i in 0..m.min(n) {
            *core.at_mut(i, i) = 8.0 / (1.0 + i as f32).powf(decay);
        }
        matmul(&matmul(&qu, &core), &qv.transpose())
    }

    fn run(method: Method, w: &Mat, rank: usize) -> QerResult {
        let q = MxintQuantizer::new(3, 32);
        let cfg = QerConfig::new(method, rank, ScalingKind::Identity);
        reconstruct(w, &q, &Scaling::Identity, &QuantCtx::default(), &cfg)
    }

    #[test]
    fn every_method_beats_or_matches_wonly() {
        let mut rng = Rng::new(400);
        let w = aniso(64, 96, 1.0, &mut rng);
        let base = run(Method::WOnly, &w, 16).weight_error(&w);
        for method in [
            Method::Qer,
            Method::QerSrr,
            Method::SrrSingleSvd,
            Method::IterativeLowRank { iters: 5 },
            Method::PreserveOnly,
            Method::FixedSplitHalf,
        ] {
            let err = run(method, &w, 16).weight_error(&w);
            assert!(err <= base * 1.001, "{}: {err} > w-only {base}", method.label());
        }
    }

    #[test]
    fn rank_budget_is_respected_by_all_methods() {
        let mut rng = Rng::new(401);
        let w = aniso(48, 64, 0.9, &mut rng);
        for method in [
            Method::Qer,
            Method::QerSrr,
            Method::SrrSingleSvd,
            Method::IterativeLowRank { iters: 3 },
            Method::PreserveOnly,
            Method::FixedSplitHalf,
        ] {
            let res = run(method, &w, 12);
            assert!(res.l.cols <= 12, "{} rank overflow", method.label());
            assert_eq!(res.l.cols, res.r.rows);
        }
    }

    #[test]
    fn srr_no_worse_than_qer_same_budget() {
        let mut rng = Rng::new(402);
        let w = aniso(96, 96, 1.3, &mut rng);
        let qer = run(Method::Qer, &w, 24).weight_error(&w);
        let srr = run(Method::QerSrr, &w, 24).weight_error(&w);
        assert!(srr <= qer * 1.02, "srr {srr} vs qer {qer}");
    }

    #[test]
    fn iterative_improves_over_single_shot_qer_at_low_bits() {
        let mut rng = Rng::new(403);
        let w = aniso(64, 64, 1.2, &mut rng);
        let q = MxintQuantizer::new(2, 32);
        let ctx = QuantCtx::default();
        let one = reconstruct(
            &w, &q, &Scaling::Identity, &ctx,
            &QerConfig::new(Method::Qer, 16, ScalingKind::Identity),
        );
        let it = reconstruct(
            &w, &q, &Scaling::Identity, &ctx,
            &QerConfig::new(Method::IterativeLowRank { iters: 5 }, 16, ScalingKind::Identity),
        );
        assert!(it.weight_error(&w) <= one.weight_error(&w) * 1.05);
    }

    #[test]
    fn selection_metadata_present_only_for_srr_family() {
        let mut rng = Rng::new(404);
        let w = aniso(32, 64, 1.0, &mut rng);
        assert!(run(Method::Qer, &w, 8).selection.is_none());
        let srr = run(Method::QerSrr, &w, 8);
        assert!(srr.selection.is_some());
        assert_eq!(srr.selection.as_ref().unwrap().k_star, srr.k_star);
    }
}
