//! Structured Residual Reconstruction — Algorithm 1 of the paper.
//!
//! Given (W, S, Q, r):
//!   1. probe E ~ U[-1,1]^{m×n}; k* ← argmin ρ_k(SW)·ρ_{r−k}(SE)   (Eq. 5)
//!   2. L⁽¹⁾R⁽¹⁾ ← S⁻¹ SVD_{k*}(SW)                     (preserve)
//!   3. Q ← quantize(W − L⁽¹⁾R⁽¹⁾)                      (quantize)
//!   4. E_k ← W − L⁽¹⁾R⁽¹⁾ − Q                          (quantization error)
//!   5. L⁽²⁾R⁽²⁾ ← S⁻¹ SVD_{r−k*}(S·E_k)                (reconstruct)
//!   6. L ← [L⁽¹⁾ L⁽²⁾],  R ← [R⁽¹⁾; R⁽²⁾]
//!
//! Steps 1–2 consume only the [`PreparedSpectra`] of (S·W, S·E): the
//! `*_prepared` entry points take them precomputed (the sweep engine
//! caches one per layer × scaling × seed and serves every config from
//! it), while `srr_decompose` remains the self-contained wrapper. The
//! preserve factors are prefix truncations of the prepared SVD, so any
//! k ≤ prep rank is served without another factorization.
//!
//! The Eq. (6) variant replaces step 5 with a single rank-r SVD of the
//! total residual W − Q (optimal for fixed Q by Eckart–Young); both are
//! exposed and compared by the ablation bench.

use crate::linalg::{randomized_svd, truncated_from};
use crate::quant::{PackedMat, QuantCtx, Quantizer};
use crate::scaling::Scaling;
use crate::tensor::{matmul, Mat};
use crate::util::Rng;

use super::rank_select::{PreparedSpectra, RankSelection};

/// SRR decomposition output. `l`/`r` hold the concatenated factors;
/// columns `0..k_star` of `l` (rows of `r`) are the preserved component.
#[derive(Clone, Debug)]
pub struct SrrOutput {
    pub qdeq: Mat,
    /// bit-packed encoding of `qdeq` for the factored serving path
    pub packed: Option<PackedMat>,
    pub l: Mat,
    pub r: Mat,
    pub k_star: usize,
    pub selection: RankSelection,
}

impl SrrOutput {
    /// W_hat = Qdeq + L·R.
    pub fn reconstruct(&self) -> Mat {
        self.qdeq.add(&matmul(&self.l, &self.r))
    }

    /// (L⁽¹⁾, R⁽¹⁾): the preserved-subspace factors.
    pub fn preserved(&self) -> (Mat, Mat) {
        (self.l.cols_slice(0, self.k_star), self.r.rows_slice(0, self.k_star))
    }

    /// (L⁽²⁾, R⁽²⁾): the error-reconstruction factors.
    pub fn residual(&self) -> (Mat, Mat) {
        (
            self.l.cols_slice(self.k_star, self.l.cols),
            self.r.rows_slice(self.k_star, self.r.rows),
        )
    }
}

/// Algorithm 1. `n_iter` = randomized-SVD power iterations (paper: 4).
///
/// Self-contained wrapper: prepares the spectra from `rng`, selects k*,
/// then runs [`srr_with_k_prepared`].
pub fn srr_decompose(
    w: &Mat,
    quantizer: &dyn Quantizer,
    scaling: &Scaling,
    ctx: &QuantCtx,
    rank: usize,
    n_iter: usize,
    rng: &mut Rng,
) -> SrrOutput {
    let spectra = PreparedSpectra::compute_with_rng(w, scaling, rank, n_iter, rng);
    let selection = spectra.select(rank);
    let k = selection.k_star;
    srr_with_k_prepared(w, quantizer, scaling, &spectra, ctx, rank, k, n_iter, rng, selection)
}

/// SRR with a fixed split k against precomputed spectra (used by the
/// dispatcher, the Fig. 2 sweep and the ODLRI-like fixed-split baseline).
/// Rank-0 / rank-r extremes degrade gracefully. The preserve factors are
/// the rank-k prefix of `spectra.sw_svd` (k ≤ `spectra.rank` required);
/// only the induced-error SVD of step 5 draws from `rng`.
#[allow(clippy::too_many_arguments)]
pub fn srr_with_k_prepared(
    w: &Mat,
    quantizer: &dyn Quantizer,
    scaling: &Scaling,
    spectra: &PreparedSpectra,
    ctx: &QuantCtx,
    rank: usize,
    k: usize,
    n_iter: usize,
    rng: &mut Rng,
    selection: RankSelection,
) -> SrrOutput {
    assert!(k <= rank);
    assert!(
        k <= spectra.rank,
        "split k={k} exceeds prepared spectra rank {}",
        spectra.rank
    );
    let (m, n) = (w.rows, w.cols);

    // (2) preserve: L1·R1 = S⁻¹ SVD_k(SW), truncated from the prepared SVD
    let (l1, r1) = if k > 0 {
        let (lu, rv) = truncated_from(&spectra.sw_svd, k);
        (scaling.unapply(&lu), rv)
    } else {
        (Mat::zeros(m, 0), Mat::zeros(0, n))
    };
    let preserved = if k > 0 { matmul(&l1, &r1) } else { Mat::zeros(m, n) };

    // (3) quantize the residual (codes kept for the factored serving path)
    let (qdeq, packed) = quantizer.quantize_coded(&w.sub(&preserved), ctx);

    // (4)+(5) reconstruct the induced quantization error with rank r−k
    let ek = w.sub(&preserved).sub(&qdeq);
    let rk = rank - k;
    let (l2, r2) = if rk > 0 {
        let sek = scaling.apply(&ek);
        let svd = randomized_svd(&sek, rk, n_iter, rng);
        let (lu, rv) = truncated_from(&svd, rk);
        (scaling.unapply(&lu), rv)
    } else {
        (Mat::zeros(m, 0), Mat::zeros(0, n))
    };

    // (6) pack
    let l = l1.hcat(&l2);
    let r = r1.vcat(&r2);
    SrrOutput { qdeq, packed, l, r, k_star: k, selection }
}

/// Self-contained fixed-split variant: prepares spectra from `rng` first.
#[allow(clippy::too_many_arguments)]
pub fn srr_with_k(
    w: &Mat,
    quantizer: &dyn Quantizer,
    scaling: &Scaling,
    ctx: &QuantCtx,
    rank: usize,
    k: usize,
    n_iter: usize,
    rng: &mut Rng,
    selection: RankSelection,
) -> SrrOutput {
    let spectra = PreparedSpectra::compute_with_rng(w, scaling, rank, n_iter, rng);
    srr_with_k_prepared(w, quantizer, scaling, &spectra, ctx, rank, k, n_iter, rng, selection)
}

/// Eq. (6) variant against precomputed spectra: same preserve-then-
/// quantize Q, but one rank-r SVD of the *total* residual W − Q replaces
/// the two-part packing.
pub fn srr_single_svd_prepared(
    w: &Mat,
    quantizer: &dyn Quantizer,
    scaling: &Scaling,
    spectra: &PreparedSpectra,
    ctx: &QuantCtx,
    rank: usize,
    n_iter: usize,
    rng: &mut Rng,
) -> SrrOutput {
    let selection = spectra.select(rank);
    let k = selection.k_star;
    let (m, n) = (w.rows, w.cols);

    let preserved = if k > 0 {
        scaling.unapply(&spectra.sw_svd.reconstruct(k))
    } else {
        Mat::zeros(m, n)
    };
    let (qdeq, packed) = quantizer.quantize_coded(&w.sub(&preserved), ctx);

    let resid = w.sub(&qdeq);
    let sresid = scaling.apply(&resid);
    let svd = randomized_svd(&sresid, rank, n_iter, rng);
    let (lu, rv) = truncated_from(&svd, rank);
    let l = scaling.unapply(&lu);
    SrrOutput { qdeq, packed, l, r: rv, k_star: k, selection }
}

/// Self-contained Eq. (6) variant: prepares spectra from `rng` first.
pub fn srr_single_svd(
    w: &Mat,
    quantizer: &dyn Quantizer,
    scaling: &Scaling,
    ctx: &QuantCtx,
    rank: usize,
    n_iter: usize,
    rng: &mut Rng,
) -> SrrOutput {
    let spectra = PreparedSpectra::compute_with_rng(w, scaling, rank, n_iter, rng);
    srr_single_svd_prepared(w, quantizer, scaling, &spectra, ctx, rank, n_iter, rng)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::qer::rank_select::select_k;
    use crate::quant::MxintQuantizer;
    use crate::util::prop;

    fn aniso(m: usize, n: usize, decay: f32, rng: &mut Rng) -> Mat {
        let (qu, _) = crate::linalg::qr_thin(&Mat::randn(m, m.min(n), 1.0, rng));
        let (qv, _) = crate::linalg::qr_thin(&Mat::randn(n, m.min(n), 1.0, rng));
        let mut core = Mat::zeros(m.min(n), m.min(n));
        for i in 0..m.min(n) {
            *core.at_mut(i, i) = 8.0 / (1.0 + i as f32).powf(decay);
        }
        matmul(&matmul(&qu, &core), &qv.transpose())
    }

    /// Dominant low-rank structure + dense noise floor: the regime where
    /// the paper's interior split k* ∈ (0, r) appears.
    fn structured(m: usize, n: usize, dom: usize, rng: &mut Rng) -> Mat {
        let sig = aniso(m, n, 2.5, rng);
        let svd = crate::linalg::jacobi_svd(&sig);
        svd.reconstruct(dom).add(&Mat::randn(m, n, 0.15, rng))
    }

    #[test]
    fn output_shapes_and_rank_bound() {
        let mut rng = Rng::new(310);
        let w = aniso(64, 96, 1.0, &mut rng);
        let q = MxintQuantizer::new(3, 32);
        let out = srr_decompose(&w, &q, &Scaling::Identity, &QuantCtx::default(), 16, 2, &mut rng);
        assert_eq!((out.l.rows, out.l.cols), (64, 16));
        assert_eq!((out.r.rows, out.r.cols), (16, 96));
        assert_eq!((out.qdeq.rows, out.qdeq.cols), (64, 96));
        let (l1, r1) = out.preserved();
        let (l2, r2) = out.residual();
        assert_eq!(l1.cols, out.k_star);
        assert_eq!(l2.cols, 16 - out.k_star);
        assert_eq!(r1.rows + r2.rows, 16);
    }

    #[test]
    fn k_zero_equals_plain_qer() {
        let mut rng = Rng::new(311);
        let w = Mat::randn(48, 64, 1.0, &mut rng);
        let q = MxintQuantizer::new(3, 32);
        let ctx = QuantCtx::default();
        let sel = select_k(&w, &Scaling::Identity, 8, 2, &mut rng);
        let mut rng2 = Rng::new(999);
        let out = srr_with_k(&w, &q, &Scaling::Identity, &ctx, 8, 0, 2, &mut rng2, sel);
        // Q must be the straight quantization of W
        assert_eq!(out.qdeq, q.quantize(&w, &ctx));
        // LR is the best rank-8 fit of the residual (allow randomized slack)
        let resid = w.sub(&out.qdeq);
        let exact = crate::linalg::jacobi_svd(&resid).reconstruct(8);
        let lr = matmul(&out.l, &out.r);
        let got = resid.sub(&lr).frob();
        let best = resid.sub(&exact).frob();
        assert!(got <= best * 1.05, "got {got} vs optimal {best}");
    }

    #[test]
    fn k_full_preserve_only() {
        let mut rng = Rng::new(312);
        let w = aniso(48, 64, 1.3, &mut rng);
        let q = MxintQuantizer::new(3, 32);
        let sel = select_k(&w, &Scaling::Identity, 8, 2, &mut rng);
        let out = srr_with_k(&w, &q, &Scaling::Identity, &QuantCtx::default(), 8, 8, 2, &mut rng, sel);
        let (l2, _) = out.residual();
        assert_eq!(l2.cols, 0);
    }

    #[test]
    fn preserved_factor_carries_more_energy_than_residual() {
        // Fig. 3a: singular values of L1R1 dominate L2R2
        let mut rng = Rng::new(313);
        let w = structured(96, 96, 10, &mut rng);
        let q = MxintQuantizer::new(3, 32);
        let out = srr_decompose(&w, &q, &Scaling::Identity, &QuantCtx::default(), 24, 4, &mut rng);
        assert!(out.k_star > 0 && out.k_star < 24, "need a genuine split, k*={}", out.k_star);
        let (l1, r1) = out.preserved();
        let (l2, r2) = out.residual();
        let e1 = matmul(&l1, &r1).frob() / out.k_star as f64;
        let e2 = matmul(&l2, &r2).frob() / (24 - out.k_star) as f64;
        assert!(e1 > e2, "preserved per-rank energy {e1} !> residual {e2}");
    }

    #[test]
    fn single_svd_variant_never_worse_than_two_part() {
        // For the same preserve-then-quantize Q, Eq. (6)'s rank-r SVD of
        // the total residual is the Eckart–Young optimum, so it can only
        // match or beat the two-part packing (up to randomized-SVD slack).
        let mut rng = Rng::new(314);
        for seed in [314u64, 315, 316] {
            let mut wrng = Rng::new(seed);
            let w = structured(64, 64, 6, &mut wrng);
            let q = MxintQuantizer::new(3, 32);
            let ctx = QuantCtx::default();
            let two = srr_decompose(&w, &q, &Scaling::Identity, &ctx, 16, 4, &mut rng);
            let one = srr_single_svd(&w, &q, &Scaling::Identity, &ctx, 16, 4, &mut rng);
            let e_two = w.sub(&two.reconstruct()).frob();
            let e_one = w.sub(&one.reconstruct()).frob();
            assert!(e_one <= e_two * 1.05, "e1={e_one} e2={e_two}");
        }
    }

    #[test]
    fn prepared_path_matches_self_contained_path() {
        // srr_decompose is literally prepare + select + srr_with_k_prepared;
        // running the pieces by hand with the same RNG must agree bitwise.
        let mut rng_a = Rng::new(317);
        let mut rng_b = Rng::new(317);
        let mut wrng = Rng::new(318);
        let w = structured(64, 96, 6, &mut wrng);
        let q = MxintQuantizer::new(3, 32);
        let ctx = QuantCtx::default();
        let a = srr_decompose(&w, &q, &Scaling::Identity, &ctx, 12, 2, &mut rng_a);
        let spectra =
            PreparedSpectra::compute_with_rng(&w, &Scaling::Identity, 12, 2, &mut rng_b);
        let sel = spectra.select(12);
        let k = sel.k_star;
        let b = srr_with_k_prepared(
            &w, &q, &Scaling::Identity, &spectra, &ctx, 12, k, 2, &mut rng_b, sel,
        );
        assert_eq!(a.qdeq, b.qdeq);
        assert_eq!(a.l, b.l);
        assert_eq!(a.r, b.r);
        assert_eq!(a.k_star, b.k_star);
    }

    #[test]
    fn prop_reconstruction_never_worse_than_wonly() {
        prop::check(0xC3, 10, |g| {
            let m = 32 + g.rng.below(32);
            let nb = 1 + g.rng.below(2);
            let n = nb * 32;
            let decay = g.f32_in(0.3, 1.5);
            let w = aniso(m, n, decay, &mut g.rng);
            let q = MxintQuantizer::new(3, 32);
            let ctx = QuantCtx::default();
            let rank = 8;
            let out = srr_decompose(&w, &q, &Scaling::Identity, &ctx, rank, 2, &mut g.rng);
            let srr_err = w.sub(&out.reconstruct()).frob();
            let wonly_err = w.sub(&q.quantize(&w, &ctx)).frob();
            assert!(srr_err <= wonly_err * 1.001, "srr {srr_err} > w-only {wonly_err}");
        });
    }
}
