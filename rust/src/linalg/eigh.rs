//! Symmetric eigendecomposition, plus the matrix square root / inverse
//! square root used to build the QERA-exact scaling S = (E[xxᵀ])^{1/2}.
//!
//! Two implementations:
//! * [`eigh`] — Householder tridiagonalization + implicit-shift QL
//!   (tred2/tqli): O(n³) once, the production path (the exact scaling
//!   needs 1536-dim Grams; Jacobi's O(n³·sweeps) was the top §Perf
//!   bottleneck before this).
//! * [`eigh_jacobi`] — classic two-sided Jacobi, kept as the simple,
//!   independently-derived oracle the tests cross-validate against.

use crate::tensor::Mat;

/// Eigendecomposition of a symmetric matrix: A = Q · diag(λ) · Qᵀ.
/// Returns (Q with eigenvectors as columns, λ descending).
pub fn eigh(a: &Mat) -> (Mat, Vec<f32>) {
    let n = a.rows;
    assert_eq!(a.rows, a.cols, "eigh needs square input");
    if n == 0 {
        return (Mat::zeros(0, 0), vec![]);
    }
    // working copy in f64; z accumulates the orthogonal transform
    let mut z: Vec<f64> = a.data.iter().map(|&x| x as f64).collect();
    let mut d = vec![0.0f64; n];
    let mut e = vec![0.0f64; n];
    tred2(&mut z, &mut d, &mut e, n);
    // transpose so tqli's plane rotations act on contiguous rows
    // (the rotation loop is the O(n³) hot spot; see EXPERIMENTS.md §Perf)
    let mut zt = vec![0.0f64; n * n];
    for i in 0..n {
        for j in 0..n {
            zt[j * n + i] = z[i * n + j];
        }
    }
    tqli(&mut d, &mut e, &mut zt, n);

    // sort descending (zt rows are eigenvectors)
    let mut idx: Vec<usize> = (0..n).collect();
    idx.sort_by(|&i, &j| d[j].partial_cmp(&d[i]).unwrap());
    let mut q = Mat::zeros(n, n);
    let mut vals = Vec::with_capacity(n);
    for (rank, &j) in idx.iter().enumerate() {
        vals.push(d[j] as f32);
        for i in 0..n {
            *q.at_mut(i, rank) = zt[j * n + i] as f32;
        }
    }
    (q, vals)
}

/// Householder reduction of a real symmetric matrix to tridiagonal form
/// (Numerical Recipes `tred2`). On exit `z` holds the accumulated
/// orthogonal transform, `d` the diagonal, `e` the sub-diagonal.
fn tred2(z: &mut [f64], d: &mut [f64], e: &mut [f64], n: usize) {
    for i in (1..n).rev() {
        let l = i - 1;
        let mut h = 0.0;
        if l > 0 {
            let mut scale = 0.0;
            for k in 0..=l {
                scale += z[i * n + k].abs();
            }
            if scale == 0.0 {
                e[i] = z[i * n + l];
            } else {
                for k in 0..=l {
                    z[i * n + k] /= scale;
                    h += z[i * n + k] * z[i * n + k];
                }
                let mut f = z[i * n + l];
                let g = if f >= 0.0 { -h.sqrt() } else { h.sqrt() };
                e[i] = scale * g;
                h -= f * g;
                z[i * n + l] = f - g;
                f = 0.0;
                for j in 0..=l {
                    z[j * n + i] = z[i * n + j] / h;
                    let mut g2 = 0.0;
                    for k in 0..=j {
                        g2 += z[j * n + k] * z[i * n + k];
                    }
                    for k in j + 1..=l {
                        g2 += z[k * n + j] * z[i * n + k];
                    }
                    e[j] = g2 / h;
                    f += e[j] * z[i * n + j];
                }
                let hh = f / (h + h);
                for j in 0..=l {
                    let fj = z[i * n + j];
                    let gj = e[j] - hh * fj;
                    e[j] = gj;
                    for k in 0..=j {
                        z[j * n + k] -= fj * e[k] + gj * z[i * n + k];
                    }
                }
            }
        } else {
            e[i] = z[i * n + l];
        }
        d[i] = h;
    }
    d[0] = 0.0;
    e[0] = 0.0;
    for i in 0..n {
        if d[i] != 0.0 {
            for j in 0..i {
                let mut g = 0.0;
                for k in 0..i {
                    g += z[i * n + k] * z[k * n + j];
                }
                for k in 0..i {
                    z[k * n + j] -= g * z[k * n + i];
                }
            }
        }
        d[i] = z[i * n + i];
        z[i * n + i] = 1.0;
        for j in 0..i {
            z[j * n + i] = 0.0;
            z[i * n + j] = 0.0;
        }
    }
}

fn pythag(a: f64, b: f64) -> f64 {
    a.hypot(b)
}

/// Implicit-shift QL on a tridiagonal matrix, accumulating eigenvectors
/// into the *rows* of `z` (transposed layout: row i = eigenvector i, so
/// each plane rotation touches two contiguous rows).
fn tqli(d: &mut [f64], e: &mut [f64], z: &mut [f64], n: usize) {
    if n <= 1 {
        return;
    }
    for i in 1..n {
        e[i - 1] = e[i];
    }
    e[n - 1] = 0.0;
    for l in 0..n {
        let mut iter = 0;
        loop {
            // find the split point
            let mut m = l;
            while m + 1 < n {
                let dd = d[m].abs() + d[m + 1].abs();
                if e[m].abs() <= f64::EPSILON * dd {
                    break;
                }
                m += 1;
            }
            if m == l {
                break;
            }
            iter += 1;
            assert!(iter <= 50, "tqli failed to converge");
            let mut g = (d[l + 1] - d[l]) / (2.0 * e[l]);
            let mut r = pythag(g, 1.0);
            g = d[m] - d[l] + e[l] / (g + r.copysign(g));
            let (mut s, mut c) = (1.0f64, 1.0f64);
            let mut p = 0.0f64;
            for i in (l..m).rev() {
                let f0 = s * e[i];
                let b = c * e[i];
                r = pythag(f0, g);
                e[i + 1] = r;
                if r == 0.0 {
                    d[i + 1] -= p;
                    e[m] = 0.0;
                    break;
                }
                s = f0 / r;
                c = g / r;
                g = d[i + 1] - p;
                r = (d[i] - g) * s + 2.0 * c * b;
                p = s * r;
                d[i + 1] = g + p;
                g = c * r - b;
                // rotate rows i and i+1 (contiguous in the transposed layout)
                let (lo, hi) = z.split_at_mut((i + 1) * n);
                let row_i = &mut lo[i * n..];
                let row_i1 = &mut hi[..n];
                for (a1, b1) in row_i.iter_mut().zip(row_i1.iter_mut()) {
                    let fv = *b1;
                    *b1 = s * *a1 + c * fv;
                    *a1 = c * *a1 - s * fv;
                }
            }
            if r == 0.0 && m > l {
                continue;
            }
            d[l] -= p;
            e[l] = g;
            e[m] = 0.0;
        }
    }
}

/// Two-sided Jacobi eigendecomposition (test oracle; O(n³·sweeps)).
pub fn eigh_jacobi(a: &Mat) -> (Mat, Vec<f32>) {
    let n = a.rows;
    assert_eq!(a.rows, a.cols, "eigh needs square input");
    let mut m: Vec<f64> = a.data.iter().map(|&x| x as f64).collect();
    let mut q = vec![0.0f64; n * n];
    for i in 0..n {
        q[i * n + i] = 1.0;
    }

    for _sweep in 0..60 {
        let mut off = 0.0f64;
        for p in 0..n {
            for r in (p + 1)..n {
                off += (m[p * n + r]).abs();
            }
        }
        if off < 1e-11 {
            break;
        }
        for p in 0..n {
            for r in (p + 1)..n {
                let apr = m[p * n + r];
                if apr.abs() < 1e-300 {
                    continue;
                }
                let app = m[p * n + p];
                let arr = m[r * n + r];
                let tau = (arr - app) / (2.0 * apr);
                let t = tau.signum() / (tau.abs() + (1.0 + tau * tau).sqrt());
                let c = 1.0 / (1.0 + t * t).sqrt();
                let s = c * t;
                // A <- Jᵀ A J on rows/cols p, r
                for k in 0..n {
                    let akp = m[k * n + p];
                    let akr = m[k * n + r];
                    m[k * n + p] = c * akp - s * akr;
                    m[k * n + r] = s * akp + c * akr;
                }
                for k in 0..n {
                    let apk = m[p * n + k];
                    let ark = m[r * n + k];
                    m[p * n + k] = c * apk - s * ark;
                    m[r * n + k] = s * apk + c * ark;
                }
                for k in 0..n {
                    let qkp = q[k * n + p];
                    let qkr = q[k * n + r];
                    q[k * n + p] = c * qkp - s * qkr;
                    q[k * n + r] = s * qkp + c * qkr;
                }
            }
        }
    }

    let mut pairs: Vec<(f64, usize)> = (0..n).map(|i| (m[i * n + i], i)).collect();
    pairs.sort_by(|a, b| b.0.partial_cmp(&a.0).unwrap());
    let mut qm = Mat::zeros(n, n);
    let mut vals = Vec::with_capacity(n);
    for (rank, &(lam, idx)) in pairs.iter().enumerate() {
        vals.push(lam as f32);
        for i in 0..n {
            *qm.at_mut(i, rank) = q[i * n + idx] as f32;
        }
    }
    (qm, vals)
}

fn sym_pow(a: &Mat, pow: f64, floor: f64) -> Mat {
    let (q, lam) = eigh(a);
    let n = a.rows;
    // Q · diag(f(λ)) · Qᵀ
    let mut qf = Mat::zeros(n, n);
    for j in 0..n {
        let l = (lam[j] as f64).max(floor);
        let f = l.powf(pow) as f32;
        for i in 0..n {
            *qf.at_mut(i, j) = q.at(i, j) * f;
        }
    }
    crate::tensor::matmul_nt(&qf, &q)
}

/// Symmetric PSD square root A^{1/2} (eigenvalues floored at `floor`).
pub fn sym_sqrt(a: &Mat, floor: f64) -> Mat {
    sym_pow(a, 0.5, floor)
}

/// Symmetric PSD inverse square root A^{-1/2}.
pub fn sym_inv_sqrt(a: &Mat, floor: f64) -> Mat {
    sym_pow(a, -0.5, floor)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::{matmul, matmul_nt, matmul_tn};
    use crate::util::Rng;

    fn random_psd(n: usize, rng: &mut Rng) -> Mat {
        let b = Mat::randn(n, n + 4, 1.0, rng);
        matmul_nt(&b, &b).scale(1.0 / (n + 4) as f32)
    }

    #[test]
    fn eigh_reconstructs() {
        let mut rng = Rng::new(30);
        for n in [1usize, 4, 16, 33] {
            let a = random_psd(n, &mut rng);
            let (q, lam) = eigh(&a);
            let mut ql = Mat::zeros(n, n);
            for j in 0..n {
                for i in 0..n {
                    *ql.at_mut(i, j) = q.at(i, j) * lam[j];
                }
            }
            let rec = matmul_nt(&ql, &q);
            assert!(rec.allclose(&a, 1e-3), "n={n}");
            let qtq = matmul_tn(&q, &q);
            assert!(qtq.allclose(&Mat::eye(n), 1e-3));
            for w in lam.windows(2) {
                assert!(w[0] >= w[1] - 1e-5);
            }
        }
    }

    #[test]
    fn sqrt_squares_back() {
        let mut rng = Rng::new(31);
        let a = random_psd(12, &mut rng);
        let s = sym_sqrt(&a, 1e-12);
        assert!(matmul(&s, &s).allclose(&a, 1e-3));
    }

    #[test]
    fn inv_sqrt_inverts_sqrt() {
        let mut rng = Rng::new(32);
        let mut a = random_psd(10, &mut rng);
        // make well-conditioned
        for i in 0..10 {
            *a.at_mut(i, i) += 1.0;
        }
        let s = sym_sqrt(&a, 1e-12);
        let si = sym_inv_sqrt(&a, 1e-12);
        assert!(matmul(&s, &si).allclose(&Mat::eye(10), 1e-3));
    }

    #[test]
    fn ql_matches_jacobi_oracle() {
        let mut rng = Rng::new(33);
        for n in [2usize, 5, 17, 40] {
            let a = random_psd(n, &mut rng);
            let (_, lam_ql) = eigh(&a);
            let (_, lam_j) = eigh_jacobi(&a);
            for (x, y) in lam_ql.iter().zip(&lam_j) {
                assert!((x - y).abs() < 1e-3 * (1.0 + y.abs()), "{x} vs {y} (n={n})");
            }
        }
    }

    #[test]
    fn eigenvalues_of_known_matrix() {
        // [[2,1],[1,2]] has eigenvalues 3 and 1
        let a = Mat::from_vec(2, 2, vec![2.0, 1.0, 1.0, 2.0]);
        let (_, lam) = eigh(&a);
        assert!((lam[0] - 3.0).abs() < 1e-5);
        assert!((lam[1] - 1.0).abs() < 1e-5);
    }
}
