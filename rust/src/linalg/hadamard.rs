//! Fast Walsh–Hadamard transform and the randomized block-Hadamard
//! rotation used by the QuIP#-sim quantizer (incoherence processing).
//!
//! QuIP# rotates W on both sides with random orthogonal matrices built
//! from H·diag(±1); we implement the same structure with the normalized
//! FWHT applied in power-of-two blocks (dimensions that are not powers of
//! two are handled block-wise, e.g. 384 = 3 × 128).

use crate::tensor::Mat;
use crate::util::Rng;

/// In-place normalized FWHT of a length-2^k slice: x ← H x / sqrt(n).
pub fn fwht_inplace(x: &mut [f32]) {
    let n = x.len();
    assert!(n.is_power_of_two(), "FWHT length must be a power of two, got {n}");
    let mut h = 1;
    while h < n {
        let mut i = 0;
        while i < n {
            for j in i..i + h {
                let a = x[j];
                let b = x[j + h];
                x[j] = a + b;
                x[j + h] = a - b;
            }
            i += 2 * h;
        }
        h *= 2;
    }
    let norm = 1.0 / (n as f32).sqrt();
    for v in x.iter_mut() {
        *v *= norm;
    }
}

fn largest_pow2_divisor(n: usize) -> usize {
    let mut b = 1;
    while n % (b * 2) == 0 {
        b *= 2;
    }
    b
}

/// Apply block FWHT along each row (i.e. right-multiply by block-diag H).
pub fn hadamard_rows(a: &mut Mat, block: usize) {
    assert!(a.cols % block == 0 && block.is_power_of_two());
    for i in 0..a.rows {
        let row = a.row_mut(i);
        for chunk in row.chunks_mut(block) {
            fwht_inplace(chunk);
        }
    }
}

/// Apply block FWHT along each column (left-multiply by block-diag H).
pub fn hadamard_cols(a: &mut Mat, block: usize) {
    assert!(a.rows % block == 0 && block.is_power_of_two());
    let mut buf = vec![0.0f32; block];
    for j in 0..a.cols {
        let mut i0 = 0;
        while i0 < a.rows {
            for i in 0..block {
                buf[i] = a.at(i0 + i, j);
            }
            fwht_inplace(&mut buf);
            for i in 0..block {
                *a.at_mut(i0 + i, j) = buf[i];
            }
            i0 += block;
        }
    }
}

/// Randomized two-sided Hadamard rotation  W ↦ (H_L D_L) W (D_R H_R),
/// with D diagonal ±1. Orthogonal, self-inverse up to the sign diagonals,
/// so `inverse()` undoes `forward()` exactly (up to f32 rounding).
pub struct RandomizedHadamard {
    pub row_block: usize,
    pub col_block: usize,
    pub sign_left: Vec<f32>,  // length = rows
    pub sign_right: Vec<f32>, // length = cols
}

impl RandomizedHadamard {
    pub fn new(rows: usize, cols: usize, rng: &mut Rng) -> Self {
        let rb = largest_pow2_divisor(rows);
        let cb = largest_pow2_divisor(cols);
        let sign = |n: usize, rng: &mut Rng| {
            (0..n).map(|_| if rng.uniform() < 0.5 { -1.0 } else { 1.0 }).collect()
        };
        RandomizedHadamard {
            row_block: rb,
            col_block: cb,
            sign_left: sign(rows, rng),
            sign_right: sign(cols, rng),
        }
    }

    /// W' = (H D_L) W (D_R H)  — the incoherent representation.
    pub fn forward(&self, w: &Mat) -> Mat {
        let mut out = w.scale_rows(&self.sign_left);
        hadamard_cols(&mut out, self.row_block);
        // right side: scale columns by sign_right then FWHT rows
        for i in 0..out.rows {
            for (j, v) in out.row_mut(i).iter_mut().enumerate() {
                *v *= self.sign_right[j];
            }
        }
        hadamard_rows(&mut out, self.col_block);
        out
    }

    /// Undo `forward`: W = D_L Hᵀ W' Hᵀ D_R (H is symmetric orthogonal).
    pub fn inverse(&self, w: &Mat) -> Mat {
        let mut out = w.clone();
        hadamard_rows(&mut out, self.col_block);
        for i in 0..out.rows {
            for (j, v) in out.row_mut(i).iter_mut().enumerate() {
                *v *= self.sign_right[j];
            }
        }
        hadamard_cols(&mut out, self.row_block);
        out.scale_rows(&self.sign_left)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fwht_is_involution() {
        let mut x: Vec<f32> = (0..16).map(|i| (i as f32) * 0.3 - 2.0).collect();
        let orig = x.clone();
        fwht_inplace(&mut x);
        fwht_inplace(&mut x);
        for (a, b) in x.iter().zip(&orig) {
            assert!((a - b).abs() < 1e-5);
        }
    }

    #[test]
    fn fwht_preserves_energy() {
        let mut rng = Rng::new(50);
        let mut x = vec![0.0f32; 64];
        rng.fill_normal(&mut x, 1.0);
        let e0: f64 = x.iter().map(|&v| (v as f64).powi(2)).sum();
        fwht_inplace(&mut x);
        let e1: f64 = x.iter().map(|&v| (v as f64).powi(2)).sum();
        assert!((e0 - e1).abs() / e0 < 1e-5);
    }

    #[test]
    fn fwht_matches_explicit_h2() {
        let mut x = vec![1.0f32, 2.0];
        fwht_inplace(&mut x);
        let s = 1.0 / 2.0f32.sqrt();
        assert!((x[0] - 3.0 * s).abs() < 1e-6);
        assert!((x[1] - (-1.0) * s).abs() < 1e-6);
    }

    #[test]
    fn randomized_hadamard_roundtrip_pow2() {
        let mut rng = Rng::new(51);
        let w = Mat::randn(64, 128, 1.0, &mut rng);
        let rh = RandomizedHadamard::new(64, 128, &mut rng);
        let rot = rh.forward(&w);
        assert!(rh.inverse(&rot).allclose(&w, 1e-4));
        // energy preserved
        assert!((rot.frob2() - w.frob2()).abs() / w.frob2() < 1e-5);
    }

    #[test]
    fn randomized_hadamard_roundtrip_non_pow2() {
        // 384 = 3·128, 96 = 3·32 — the base model's shapes
        let mut rng = Rng::new(52);
        let w = Mat::randn(96, 384, 1.0, &mut rng);
        let rh = RandomizedHadamard::new(96, 384, &mut rng);
        assert_eq!(rh.row_block, 32);
        assert_eq!(rh.col_block, 128);
        let rot = rh.forward(&w);
        assert!(rh.inverse(&rot).allclose(&w, 1e-4));
    }

    #[test]
    fn rotation_reduces_max_abs_of_spiky_matrix() {
        // incoherence processing should spread an outlier column
        let mut w = Mat::zeros(64, 64);
        for i in 0..64 {
            *w.at_mut(i, 3) = 10.0;
        }
        let mut rng = Rng::new(53);
        let rh = RandomizedHadamard::new(64, 64, &mut rng);
        let rot = rh.forward(&w);
        assert!(rot.max_abs() < w.max_abs());
    }
}
