//! Cholesky factorization + solver (GPTQ's damped Hessian inverse path).

use crate::tensor::Mat;

/// Lower-triangular L with A = L·Lᵀ. Returns None if A is not PD.
pub fn cholesky(a: &Mat) -> Option<Mat> {
    let n = a.rows;
    assert_eq!(a.rows, a.cols);
    let mut l = vec![0.0f64; n * n];
    for i in 0..n {
        for j in 0..=i {
            let mut sum = a.at(i, j) as f64;
            for k in 0..j {
                sum -= l[i * n + k] * l[j * n + k];
            }
            if i == j {
                if sum <= 0.0 {
                    return None;
                }
                l[i * n + j] = sum.sqrt();
            } else {
                l[i * n + j] = sum / l[j * n + j];
            }
        }
    }
    Some(Mat::from_vec(n, n, l.iter().map(|&x| x as f32).collect()))
}

/// Solve A x = b for symmetric PD A via Cholesky. b may have many columns.
pub fn cholesky_solve(a: &Mat, b: &Mat) -> Option<Mat> {
    let l = cholesky(a)?;
    let n = a.rows;
    let mut x = b.clone();
    // forward solve L y = b
    for col in 0..b.cols {
        for i in 0..n {
            let mut s = x.at(i, col) as f64;
            for k in 0..i {
                s -= l.at(i, k) as f64 * x.at(k, col) as f64;
            }
            *x.at_mut(i, col) = (s / l.at(i, i) as f64) as f32;
        }
        // back solve Lᵀ x = y
        for i in (0..n).rev() {
            let mut s = x.at(i, col) as f64;
            for k in i + 1..n {
                s -= l.at(k, i) as f64 * x.at(k, col) as f64;
            }
            *x.at_mut(i, col) = (s / l.at(i, i) as f64) as f32;
        }
    }
    Some(x)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::{matmul, matmul_nt};
    use crate::util::Rng;

    #[test]
    fn factor_reconstructs() {
        let mut rng = Rng::new(40);
        let b = Mat::randn(8, 12, 1.0, &mut rng);
        let mut a = matmul_nt(&b, &b);
        for i in 0..8 {
            *a.at_mut(i, i) += 0.5;
        }
        let l = cholesky(&a).expect("PD");
        let rec = matmul_nt(&l, &l);
        assert!(rec.allclose(&a, 1e-3));
        for i in 0..8 {
            for j in i + 1..8 {
                assert_eq!(l.at(i, j), 0.0);
            }
        }
    }

    #[test]
    fn non_pd_returns_none() {
        let a = Mat::from_vec(2, 2, vec![1.0, 2.0, 2.0, 1.0]); // indefinite
        assert!(cholesky(&a).is_none());
    }

    #[test]
    fn solve_recovers_solution() {
        let mut rng = Rng::new(41);
        let b = Mat::randn(6, 9, 1.0, &mut rng);
        let mut a = matmul_nt(&b, &b);
        for i in 0..6 {
            *a.at_mut(i, i) += 1.0;
        }
        let x_true = Mat::randn(6, 3, 1.0, &mut rng);
        let rhs = matmul(&a, &x_true);
        let x = cholesky_solve(&a, &rhs).unwrap();
        assert!(x.allclose(&x_true, 1e-2));
    }
}
