//! Thin QR via Householder reflections.

use crate::tensor::Mat;

/// Thin QR of A (m×n, m ≥ n): returns (Q m×n with orthonormal columns,
/// R n×n upper triangular) with A = Q·R.
pub fn qr_thin(a: &Mat) -> (Mat, Mat) {
    let (m, n) = (a.rows, a.cols);
    assert!(m >= n, "qr_thin expects tall matrix, got {m}x{n}");
    // Work in f64 for stability of reflectors.
    let mut r: Vec<f64> = a.data.iter().map(|&x| x as f64).collect();
    let mut vs: Vec<Vec<f64>> = Vec::with_capacity(n); // Householder vectors

    for k in 0..n {
        // norm of column k below the diagonal
        let mut norm2 = 0.0;
        for i in k..m {
            let x = r[i * n + k];
            norm2 += x * x;
        }
        let norm = norm2.sqrt();
        let mut v = vec![0.0; m - k];
        if norm > 0.0 {
            let x0 = r[k * n + k];
            let alpha = if x0 >= 0.0 { -norm } else { norm };
            v[0] = x0 - alpha;
            for i in k + 1..m {
                v[i - k] = r[i * n + k];
            }
            let vnorm2: f64 = v.iter().map(|x| x * x).sum();
            if vnorm2 > 1e-300 {
                // apply H = I - 2 v vᵀ / (vᵀv) to R[k.., k..]
                for j in k..n {
                    let mut dot = 0.0;
                    for i in k..m {
                        dot += v[i - k] * r[i * n + j];
                    }
                    let f = 2.0 * dot / vnorm2;
                    for i in k..m {
                        r[i * n + j] -= f * v[i - k];
                    }
                }
            }
        }
        vs.push(v);
    }

    // Accumulate Q = H_0 · … · H_{n-1} · [I_n; 0]
    let mut q = vec![0.0f64; m * n];
    for j in 0..n {
        q[j * n + j] = 1.0;
    }
    for k in (0..n).rev() {
        let v = &vs[k];
        let vnorm2: f64 = v.iter().map(|x| x * x).sum();
        if vnorm2 <= 1e-300 {
            continue;
        }
        for j in 0..n {
            let mut dot = 0.0;
            for i in k..m {
                dot += v[i - k] * q[i * n + j];
            }
            let f = 2.0 * dot / vnorm2;
            for i in k..m {
                q[i * n + j] -= f * v[i - k];
            }
        }
    }

    let qm = Mat::from_vec(m, n, q.iter().map(|&x| x as f32).collect());
    let mut rm = Mat::zeros(n, n);
    for i in 0..n {
        for j in i..n {
            *rm.at_mut(i, j) = r[i * n + j] as f32;
        }
    }
    (qm, rm)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::{matmul, matmul_tn};
    use crate::util::Rng;

    #[test]
    fn reconstructs_and_orthonormal() {
        let mut rng = Rng::new(10);
        for &(m, n) in &[(8, 8), (20, 5), (64, 32), (7, 1)] {
            let a = Mat::randn(m, n, 1.0, &mut rng);
            let (q, r) = qr_thin(&a);
            assert!(matmul(&q, &r).allclose(&a, 1e-4), "A=QR failed {m}x{n}");
            let qtq = matmul_tn(&q, &q);
            assert!(qtq.allclose(&Mat::eye(n), 1e-4), "QtQ!=I {m}x{n}");
            // R upper triangular
            for i in 0..n {
                for j in 0..i {
                    assert_eq!(r.at(i, j), 0.0);
                }
            }
        }
    }

    #[test]
    fn rank_deficient_input_is_stable() {
        let mut rng = Rng::new(11);
        let b = Mat::randn(16, 2, 1.0, &mut rng);
        let c = Mat::randn(2, 6, 1.0, &mut rng);
        let a = matmul(&b, &c); // rank 2, 16x6
        let (q, r) = qr_thin(&a);
        assert!(matmul(&q, &r).allclose(&a, 1e-4));
        assert!(q.data.iter().all(|v| v.is_finite()));
    }
}
