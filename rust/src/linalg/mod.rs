//! Dense factorizations built from scratch (no BLAS/LAPACK):
//!
//! * [`qr`] — Householder thin QR (range finder backbone)
//! * [`svd`] — one-sided Jacobi SVD (exact, small) + randomized truncated
//!   SVD (Halko et al. 2011; n_iter = 4, oversample = 2r, matching the
//!   paper's §A.4 configuration)
//! * [`eigh`] — two-sided Jacobi symmetric eigendecomposition (for
//!   `S = (E[xxᵀ])^{1/2}` in QERA-exact)
//! * [`chol`] — Cholesky (GPTQ's damped Hessian inverse)
//! * [`hadamard`] — fast Walsh–Hadamard transform (QuIP#-sim incoherence)

mod qr;
mod svd;
mod eigh;
mod chol;
mod hadamard;

pub use chol::{cholesky, cholesky_solve};
pub use eigh::{eigh, eigh_jacobi, sym_inv_sqrt, sym_sqrt};
pub use hadamard::{fwht_inplace, hadamard_rows, hadamard_cols, RandomizedHadamard};
pub use qr::qr_thin;
pub use svd::{jacobi_svd, randomized_svd, truncated_from, Svd};

use crate::tensor::Mat;

/// Unrecoverable energy ratio ρ_p(A) = 1 − Σ_{j≤p} σ_j² / ‖A‖_F²   (paper §4.2).
///
/// `sv` are the leading singular values (descending) of A, `frob2` = ‖A‖_F².
/// `p` may exceed `sv.len()` only if the tail is already ~zero.
pub fn rho(sv: &[f32], frob2: f64, p: usize) -> f64 {
    let head: f64 = sv.iter().take(p).map(|&s| (s as f64) * (s as f64)).sum();
    if frob2 <= 0.0 {
        return 0.0;
    }
    (1.0 - head / frob2).max(0.0)
}

/// Dimension-normalized effective rank  eRank(A) = exp(−Σ p_i log p_i),
/// p_i = σ_i / Σσ  (paper §C.3). Needs the *full* spectrum.
pub fn effective_rank(sv: &[f32]) -> f64 {
    let total: f64 = sv.iter().map(|&s| s as f64).sum();
    if total <= 0.0 {
        return 0.0;
    }
    let mut h = 0.0;
    for &s in sv {
        let p = s as f64 / total;
        if p > 1e-300 {
            h -= p * p.ln();
        }
    }
    h.exp()
}

/// Build the rank-k truncation L·R from an SVD, with the paper's
/// factorization convention (§A.3): L = U_k (orthonormal), R = Σ_k V_kᵀ.
pub fn lr_from_svd(svd: &Svd, k: usize) -> (Mat, Mat) {
    truncated_from(svd, k)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rho_monotone_nonincreasing_in_p() {
        let sv = [5.0f32, 3.0, 2.0, 1.0, 0.5];
        let frob2: f64 = sv.iter().map(|&s| (s as f64).powi(2)).sum();
        let rs: Vec<f64> = (0..=5).map(|p| rho(&sv, frob2, p)).collect();
        for w in rs.windows(2) {
            assert!(w[1] <= w[0] + 1e-12);
        }
        assert!((rs[0] - 1.0).abs() < 1e-12);
        assert!(rs[5].abs() < 1e-9);
    }

    #[test]
    fn effective_rank_extremes() {
        // rank-1 spectrum -> eRank 1; flat spectrum of n -> eRank n
        assert!((effective_rank(&[7.0, 0.0, 0.0]) - 1.0).abs() < 1e-9);
        let flat = [2.0f32; 16];
        assert!((effective_rank(&flat) - 16.0).abs() < 1e-4);
    }
}
