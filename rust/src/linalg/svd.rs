//! SVD: exact one-sided Jacobi (small/medium matrices, tests' ground
//! truth) and randomized truncated SVD (Halko–Martinsson–Tropp), the
//! production path SRR uses exactly as the paper configures it (§A.4:
//! n_iter = 4 power iterations, oversampling = 2× target rank).

use crate::tensor::{matmul, matmul_tn, Mat};
use crate::util::Rng;

use super::qr::qr_thin;

/// Thin SVD A = U · diag(s) · Vᵀ with U m×r, V n×r, s descending.
#[derive(Clone, Debug)]
pub struct Svd {
    pub u: Mat,
    pub s: Vec<f32>,
    pub v: Mat,
}

impl Svd {
    /// Reconstruct U_k Σ_k V_kᵀ.
    pub fn reconstruct(&self, k: usize) -> Mat {
        let k = k.min(self.s.len());
        let uk = self.u.cols_slice(0, k);
        let vk = self.v.cols_slice(0, k);
        let us = Mat::from_fn(uk.rows, k, |i, j| uk.at(i, j) * self.s[j]);
        crate::tensor::matmul_nt(&us, &vk)
    }
}

/// Paper §A.3 factorization: L = U_k (orthonormal), R = Σ_k V_kᵀ.
pub fn truncated_from(svd: &Svd, k: usize) -> (Mat, Mat) {
    let k = k.min(svd.s.len());
    let l = svd.u.cols_slice(0, k);
    let vk = svd.v.cols_slice(0, k);
    let r = Mat::from_fn(k, vk.rows, |i, j| svd.s[i] * vk.at(j, i));
    (l, r)
}

/// Exact SVD via one-sided Jacobi on the columns of A (m×n). Handles any
/// aspect ratio (transposes internally when m < n). O(m n² · sweeps).
pub fn jacobi_svd(a: &Mat) -> Svd {
    if a.rows < a.cols {
        let t = jacobi_svd(&a.transpose());
        return Svd { u: t.v, s: t.s, v: t.u };
    }
    let (m, n) = (a.rows, a.cols);
    // f64 working copy, column-major for cheap column ops
    let mut w: Vec<Vec<f64>> = (0..n)
        .map(|j| (0..m).map(|i| a.at(i, j) as f64).collect())
        .collect();
    let mut v = vec![vec![0.0f64; n]; n];
    for (j, col) in v.iter_mut().enumerate() {
        col[j] = 1.0;
    }

    let eps = 1e-12;
    for _sweep in 0..60 {
        let mut off = 0.0f64;
        for p in 0..n {
            for q in (p + 1)..n {
                let mut app = 0.0;
                let mut aqq = 0.0;
                let mut apq = 0.0;
                for i in 0..m {
                    app += w[p][i] * w[p][i];
                    aqq += w[q][i] * w[q][i];
                    apq += w[p][i] * w[q][i];
                }
                if apq.abs() <= eps * (app * aqq).sqrt().max(1e-300) {
                    continue;
                }
                off += apq.abs();
                let tau = (aqq - app) / (2.0 * apq);
                let t = tau.signum() / (tau.abs() + (1.0 + tau * tau).sqrt());
                let c = 1.0 / (1.0 + t * t).sqrt();
                let s = c * t;
                for i in 0..m {
                    let wp = w[p][i];
                    let wq = w[q][i];
                    w[p][i] = c * wp - s * wq;
                    w[q][i] = s * wp + c * wq;
                }
                for i in 0..n {
                    let vp = v[p][i];
                    let vq = v[q][i];
                    v[p][i] = c * vp - s * vq;
                    v[q][i] = s * vp + c * vq;
                }
            }
        }
        if off < 1e-14 {
            break;
        }
    }

    // singular values = column norms; sort descending
    let mut svals: Vec<(f64, usize)> = (0..n)
        .map(|j| ((0..m).map(|i| w[j][i] * w[j][i]).sum::<f64>().sqrt(), j))
        .collect();
    svals.sort_by(|a, b| b.0.partial_cmp(&a.0).unwrap());

    let mut u = Mat::zeros(m, n);
    let mut vm = Mat::zeros(n, n);
    let mut s = Vec::with_capacity(n);
    for (rank, &(sv, j)) in svals.iter().enumerate() {
        s.push(sv as f32);
        if sv > 1e-300 {
            for i in 0..m {
                *u.at_mut(i, rank) = (w[j][i] / sv) as f32;
            }
        }
        for i in 0..n {
            *vm.at_mut(i, rank) = v[j][i] as f32;
        }
    }
    Svd { u, s, v: vm }
}

/// Randomized truncated SVD (Halko et al. 2011).
///
/// Matches the paper's §A.4 setup: oversampling 2× the target rank and 4
/// power iterations with QR re-orthonormalization. Returns the leading
/// `k` triplets; also returns exact leading spectra up to k.
pub fn randomized_svd(a: &Mat, k: usize, n_iter: usize, rng: &mut Rng) -> Svd {
    let (m, n) = (a.rows, a.cols);
    let kmax = k.min(m.min(n));
    if kmax == 0 {
        return Svd { u: Mat::zeros(m, 0), s: vec![], v: Mat::zeros(n, 0) };
    }
    // If oversampled width is within ~2x of the small dimension, exact
    // Jacobi is cheaper and exact.
    let p = (2 * kmax).min(m.min(n));
    if p * 2 >= m.min(n) {
        let full = jacobi_svd(a);
        return Svd {
            u: full.u.cols_slice(0, kmax),
            s: full.s[..kmax].to_vec(),
            v: full.v.cols_slice(0, kmax),
        };
    }

    let omega = Mat::randn(n, p, 1.0, rng);
    let mut q = qr_thin(&matmul(a, &omega)).0; // m×p
    for _ in 0..n_iter {
        let z = qr_thin(&matmul_tn(a, &q)).0; // n×p
        q = qr_thin(&matmul(a, &z)).0;
    }
    let b = matmul_tn(&q, a); // p×n
    let bs = jacobi_svd(&b); // b = Ub S Vbᵀ, Ub p×p', V n×p'
    let u = matmul(&q, &bs.u.cols_slice(0, kmax));
    Svd { u, s: bs.s[..kmax].to_vec(), v: bs.v.cols_slice(0, kmax) }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::matmul_nt;

    fn low_rank(m: usize, n: usize, r: usize, rng: &mut Rng) -> Mat {
        let b = Mat::randn(m, r, 1.0, rng);
        let c = Mat::randn(r, n, 1.0, rng);
        matmul(&b, &c)
    }

    #[test]
    fn jacobi_reconstructs_exactly() {
        let mut rng = Rng::new(20);
        for &(m, n) in &[(10, 6), (6, 10), (16, 16), (5, 1)] {
            let a = Mat::randn(m, n, 1.0, &mut rng);
            let svd = jacobi_svd(&a);
            let rec = svd.reconstruct(m.min(n));
            assert!(rec.allclose(&a, 1e-3), "reconstruct failed {m}x{n}");
            // descending spectrum
            for w in svd.s.windows(2) {
                assert!(w[0] >= w[1] - 1e-6);
            }
            // orthonormal factors
            let utu = matmul_tn(&svd.u, &svd.u);
            let vtv = matmul_tn(&svd.v, &svd.v);
            // allow tiny-rank null columns: check diag<=1, offdiag ~0 where s>0
            let r = svd.s.iter().filter(|&&s| s > 1e-4).count();
            for i in 0..r {
                for j in 0..r {
                    let want = if i == j { 1.0 } else { 0.0 };
                    assert!((utu.at(i, j) - want).abs() < 1e-3);
                    assert!((vtv.at(i, j) - want).abs() < 1e-3);
                }
            }
        }
    }

    #[test]
    fn jacobi_matches_known_singular_values() {
        // diag(3,2,1) embedded in a rotation-free matrix
        let a = Mat::from_fn(3, 3, |i, j| if i == j { (3 - i) as f32 } else { 0.0 });
        let svd = jacobi_svd(&a);
        assert!((svd.s[0] - 3.0).abs() < 1e-5);
        assert!((svd.s[1] - 2.0).abs() < 1e-5);
        assert!((svd.s[2] - 1.0).abs() < 1e-5);
    }

    #[test]
    fn eckart_young_truncation_is_optimal() {
        // residual after rank-k truncation == sqrt(sum of tail sv^2)
        let mut rng = Rng::new(21);
        let a = Mat::randn(12, 9, 1.0, &mut rng);
        let svd = jacobi_svd(&a);
        for k in [1usize, 3, 6] {
            let rec = svd.reconstruct(k);
            let resid = a.sub(&rec).frob();
            let tail: f64 = svd.s[k..].iter().map(|&s| (s as f64).powi(2)).sum();
            assert!((resid - tail.sqrt()).abs() < 1e-3, "k={k}: {resid} vs {}", tail.sqrt());
        }
    }

    #[test]
    fn randomized_recovers_low_rank_exactly() {
        let mut rng = Rng::new(22);
        let a = low_rank(60, 40, 5, &mut rng);
        let svd = randomized_svd(&a, 5, 4, &mut rng);
        let rec = svd.reconstruct(5);
        let rel = a.sub(&rec).frob() / a.frob();
        assert!(rel < 1e-3, "rel={rel}");
    }

    #[test]
    fn randomized_spectrum_close_to_jacobi() {
        let mut rng = Rng::new(23);
        let a = Mat::randn(80, 50, 1.0, &mut rng);
        let exact = jacobi_svd(&a);
        let approx = randomized_svd(&a, 10, 4, &mut rng);
        for i in 0..10 {
            let rel = (approx.s[i] - exact.s[i]).abs() / exact.s[i];
            assert!(rel < 0.05, "sv {i}: {} vs {}", approx.s[i], exact.s[i]);
        }
    }

    #[test]
    fn truncated_from_has_orthonormal_left_factor() {
        let mut rng = Rng::new(24);
        let a = Mat::randn(20, 14, 1.0, &mut rng);
        let svd = jacobi_svd(&a);
        let (l, r) = truncated_from(&svd, 4);
        assert_eq!((l.rows, l.cols), (20, 4));
        assert_eq!((r.rows, r.cols), (4, 14));
        let ltl = matmul_tn(&l, &l);
        assert!(ltl.allclose(&Mat::eye(4), 1e-3));
        // L·R equals the rank-4 reconstruction
        assert!(matmul(&l, &r).allclose(&svd.reconstruct(4), 1e-3));
        let _ = matmul_nt(&l, &l); // exercise nt path for coverage
    }

    #[test]
    fn zero_rank_request() {
        let mut rng = Rng::new(25);
        let a = Mat::randn(6, 4, 1.0, &mut rng);
        let svd = randomized_svd(&a, 0, 2, &mut rng);
        assert_eq!(svd.s.len(), 0);
        assert_eq!(svd.u.cols, 0);
    }
}
