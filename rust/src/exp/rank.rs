//! Rank-selection analyses: Figures 2, 3, 5 and Tables 12, 20/21.

use anyhow::Result;

use crate::coordinator::QuantizerSpec;
use crate::model::Params;
use crate::qer::assumptions::{eta_q, proxy_alignment};
use crate::qer::rank_select::{select_k, PreparedSpectra};
use crate::qer::srr::srr_with_k_prepared;
use crate::scaling::ScalingKind;
use crate::tensor::matmul;
use crate::util::bench::{f, Table};
use crate::util::stats;
use crate::util::Rng;

use super::fixtures::ExpCtx;

const PROJ: [(&str, &str); 7] = [
    ("Query", "wq"),
    ("Key", "wk"),
    ("Value", "wv"),
    ("Output", "wo"),
    ("Gate", "gate"),
    ("Up", "up"),
    ("Down", "down"),
];

/// Fig. 2 / 6: actual reconstruction error L(k) vs the surrogate
/// objective over k, for the Query and Output projections.
pub fn fig2(ctx: &mut ExpCtx) -> Result<Vec<Table>> {
    let model = "tiny";
    let fx = ctx.lm(model)?;
    let layer = fx.cfg.n_layers / 2;
    let rank = 8;
    let quant = QuantizerSpec::Mxint { bits: 3, block: 32 };
    let mut tables = vec![];
    for (label, kind) in [("Query", "wq"), ("Output", "wo")] {
        let name = format!("l{layer}.{kind}");
        let w = fx.params.get_mat(&name)?;
        let scaling = fx.calib.scaling_for(&name, ScalingKind::Exact);
        // shared-work: one spectra preparation serves the selection and
        // every fixed-k decomposition of the sweep below (the preserve
        // factors are prefix truncations of the same SVD)
        let mut rng = Rng::new(42);
        let spectra = PreparedSpectra::compute_with_rng(&w, &scaling, rank, 4, &mut rng);
        let sel = spectra.select(rank);
        let mut t = Table::new(
            &format!("Fig. 2 analog — L(k) vs surrogate, {label} (layer {layer}, r={rank}, model={model})"),
            &["k", "actual L(k)", "surrogate", "selected"],
        );
        let q = quant.build();
        let ctxq = Default::default();
        let actuals: Vec<f64> = (0..=rank)
            .map(|k| {
                let mut rng2 = Rng::new(43);
                let out = srr_with_k_prepared(
                    &w, q.as_ref(), &scaling, &spectra, &ctxq, rank, k, 4, &mut rng2,
                    sel.clone(),
                );
                scaling.apply(&w.sub(&out.reconstruct())).frob()
            })
            .collect();
        for (k, actual) in actuals.iter().enumerate() {
            t.row(vec![
                k.to_string(),
                f(*actual, 4),
                f(sel.objective[k], 5),
                if k == sel.k_star { "<- k*".into() } else { String::new() },
            ]);
        }
        // alignment check: the two curves should rank k's similarly
        let rho = stats::spearman(&actuals, &sel.objective);
        t.row(vec!["spearman(actual,surrogate)".into(), f(rho, 3), String::new(), String::new()]);
        tables.push(t);
    }
    Ok(tables)
}

/// Fig. 3a: singular spectrum of the packed adapter L·R with the k* split.
pub fn fig3(ctx: &mut ExpCtx) -> Result<Vec<Table>> {
    let model = "tiny";
    let fx = ctx.lm(model)?;
    let name = format!("l{}.wq", fx.cfg.n_layers / 2);
    let w = fx.params.get_mat(&name)?;
    let scaling = fx.calib.scaling_for(&name, ScalingKind::Exact);
    let quant = QuantizerSpec::Mxint { bits: 3, block: 32 };
    let q = quant.build();
    let mut rng = Rng::new(7);
    let out = crate::qer::srr::srr_decompose(
        &w, q.as_ref(), &scaling, &Default::default(), 8, 4, &mut rng,
    );
    let lr = matmul(&out.l, &out.r);
    let svd = crate::linalg::jacobi_svd(&lr);
    let mut t = Table::new(
        &format!("Fig. 3a analog — singular spectrum of L·R, k*={} ({name}, model={model})", out.k_star),
        &["i", "sigma_i", "component"],
    );
    for i in 0..8 {
        t.row(vec![
            i.to_string(),
            f(svd.s[i] as f64, 5),
            if i < out.k_star { "preserved".into() } else { "residual".into() },
        ]);
    }
    // the preserved block must dominate (paper Fig. 3a)
    let e1: f64 = svd.s[..out.k_star].iter().map(|&s| (s as f64).powi(2)).sum();
    let e2: f64 = svd.s[out.k_star..8.min(svd.s.len())].iter().map(|&s| (s as f64).powi(2)).sum();
    t.row(vec!["energy".into(), f(e1, 4), format!("preserved vs residual {}", f(e2, 4))]);
    Ok(vec![t])
}

/// Fig. 5: distribution of selected k* per projection type across layers.
pub fn fig5(ctx: &mut ExpCtx) -> Result<Vec<Table>> {
    let models: Vec<&str> = if ctx.quick { vec!["tiny"] } else { vec!["tiny", "base"] };
    let rank = 8;
    let mut tables = vec![];
    for model in models {
        let fx = ctx.lm(model)?;
        let mut t = Table::new(
            &format!("Fig. 5 analog — k* distribution by projection (r={rank}, model={model})"),
            &["projection", "min", "q1", "median", "q3", "max"],
        );
        for (label, kind) in PROJ {
            let mut ks = vec![];
            for layer in 0..fx.cfg.n_layers {
                let name = format!("l{layer}.{kind}");
                let w = fx.params.get_mat(&name)?;
                let scaling = fx.calib.scaling_for(&name, ScalingKind::Exact);
                let mut rng = Rng::new(11 + layer as u64);
                ks.push(select_k(&w, &scaling, rank, 4, &mut rng).k_star as f64);
            }
            let (mn, q1, md, q3, mx) = stats::box_stats(&ks);
            t.row(vec![label.into(), f(mn, 0), f(q1, 1), f(md, 1), f(q3, 1), f(mx, 0)]);
        }
        tables.push(t);
    }
    Ok(tables)
}

/// Table 12: stability of k* across probe seeds.
pub fn table12(ctx: &mut ExpCtx) -> Result<Vec<Table>> {
    let model = "tiny";
    let fx = ctx.lm(model)?;
    let rank = 8;
    let mut t = Table::new(
        &format!("Table 12 analog — k* stability across probe seeds (r={rank}, model={model})"),
        &["projection", "mean |dk*|", "max |dk*|"],
    );
    for (label, kind) in PROJ {
        let mut diffs = vec![];
        for layer in 0..fx.cfg.n_layers {
            let name = format!("l{layer}.{kind}");
            let w = fx.params.get_mat(&name)?;
            let scaling = fx.calib.scaling_for(&name, ScalingKind::Exact);
            let mut k_by_seed = vec![];
            for seed in [100u64, 200] {
                let mut rng = Rng::new(seed + layer as u64);
                k_by_seed.push(select_k(&w, &scaling, rank, 4, &mut rng).k_star as i64);
            }
            diffs.push((k_by_seed[0] - k_by_seed[1]).unsigned_abs() as f64);
        }
        t.row(vec![
            label.into(),
            f(stats::mean(&diffs), 1),
            f(diffs.iter().cloned().fold(0.0, f64::max), 0),
        ]);
    }
    Ok(vec![t])
}

/// Tables 20/21: Assumption 4.1 (CV of η_Q) and 4.2 (proxy MRE) validation.
pub fn table20(ctx: &mut ExpCtx) -> Result<Vec<Table>> {
    let model = "tiny";
    let fx = ctx.lm(model)?;
    let rank = 8;
    let quants: Vec<(&str, QuantizerSpec)> = vec![
        ("MXINT-3", QuantizerSpec::Mxint { bits: 3, block: 32 }),
        ("MXINT-4", QuantizerSpec::Mxint { bits: 4, block: 32 }),
        ("GPTQ-3", QuantizerSpec::Gptq { bits: 3, group: 128 }),
    ];
    let mut t = Table::new(
        &format!("Table 20/21 analog — assumption validation (model={model})"),
        &["quantizer", "CV(eta_Q) (Asm 4.1)", "MRE (Asm 4.2)"],
    );
    let names = Params::linear_names(&fx.cfg);
    for (label, spec) in quants {
        let q = spec.build();
        let mut etas = vec![];
        let mut mres = vec![];
        for name in names.iter().take(if ctx.quick { 4 } else { names.len() }) {
            let w = fx.params.get_mat(name)?;
            let scaling = fx.calib.scaling_for(name, ScalingKind::Exact);
            let qctx = fx.calib.quant_ctx(name, spec.needs_hessian(), 3);
            etas.push(eta_q(&w, q.as_ref(), &scaling, &qctx));
            if name.ends_with("wq") || name.ends_with("wo") {
                let mut rng = Rng::new(5);
                let (_, _, mre) =
                    proxy_alignment(&w, q.as_ref(), &scaling, &qctx, rank, 4, 2, &mut rng);
                mres.push(mre);
            }
        }
        t.row(vec![
            label.into(),
            f(stats::coeff_of_variation(&etas), 4),
            f(stats::mean(&mres), 4),
        ]);
    }
    Ok(vec![t])
}
