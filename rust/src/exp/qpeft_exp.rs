//! QPEFT experiments: Tables 3, 4, 6, 18, 19 and Figure 4.

use anyhow::Result;

use crate::coordinator::QuantizerSpec;
use crate::data::glue_sim::{GlueTask, Metric};
use crate::data::gsm_sim::GsmSim;
use crate::eval::{glue_score, gsm_exact_match, perplexity};
use crate::model::Params;
use crate::qpeft::{init_qpeft, GradScale, QpeftInit, QpeftState, QpeftTrainer};
use crate::runtime::{Executor, TensorValue};
use crate::tensor::{matmul, Mat};
use crate::util::bench::{f, Table};
use crate::util::stats;
use crate::util::Rng;

use super::fixtures::ExpCtx;

/// The paper's bit → rank pairing (§A.3): 4/3-bit GLUE use r=8, the
/// 2-bit GLUE + GSM settings use r=64. (Artifacts exist for both.)
fn rank_for_bits(bits: u32) -> usize {
    if bits == 2 {
        64
    } else {
        8
    }
}

fn steps(ctx: &ExpCtx, full: usize) -> usize {
    if ctx.quick {
        full.min(12)
    } else {
        full
    }
}

// ---------------------------------------------------------------------------
// GLUE-sim machinery
// ---------------------------------------------------------------------------

struct GlueEnv {
    tasks: Vec<GlueTask>,
    batch: usize,
    seq: usize,
}

fn glue_env(ctx: &mut ExpCtx) -> Result<GlueEnv> {
    let m = ctx.engine.manifest();
    let (batch, seq) = (m.cls_batch, m.cls_seq);
    let vocab = m.model("tiny")?.vocab;
    let (n_train, n_dev) = if ctx.quick { (48, 32) } else { (256, 64) };
    Ok(GlueEnv { tasks: GlueTask::all(vocab, seq, n_train, n_dev, 9090), batch, seq })
}

fn head_init(cfg: &crate::runtime::manifest::ModelCfg, n_out: usize, seed: u64) -> Mat {
    let mut rng = Rng::new(seed);
    Mat::randn(cfg.d_model, n_out, 0.02, &mut rng)
}

/// Train one (task, init, bits, scale) configuration; returns
/// (metric score, loss curve).
#[allow(clippy::too_many_arguments)]
fn run_glue(
    ctx: &mut ExpCtx,
    env: &GlueEnv,
    task: &GlueTask,
    init: QpeftInit,
    bits: u32,
    scale: GradScale,
    lr: f32,
    n_steps: usize,
) -> Result<(f64, Vec<f32>)> {
    let fx = ctx.lm("tiny")?;
    let rank = rank_for_bits(bits);
    let reg = task.metric == Metric::PearsonSpearman;
    let (train_art, fwd_art) = if reg {
        (format!("qpeft_cls_train_reg_tiny_r{rank}"), format!("qpeft_cls_fwd_reg_tiny_r{rank}"))
    } else {
        (format!("qpeft_cls_train_tiny_r{rank}"), format!("qpeft_cls_fwd_tiny_r{rank}"))
    };
    let n_out = if reg { 1 } else { ctx.engine.manifest().cls_classes };
    let quant = QuantizerSpec::Mxint { bits, block: 32 };
    let state = init_qpeft(
        &fx.params, &fx.cfg, &fx.calib, quant, init, rank,
        head_init(&fx.cfg, n_out, 777), ctx.seed,
    );
    let mut trainer = QpeftTrainer::new(&ctx.engine, &train_art, state, lr, scale);

    for step in 0..n_steps {
        let (toks, labels_i, labels_f) =
            GlueTask::batch(&task.train, step * env.batch, env.batch, env.seq);
        let tokens = TensorValue::i32(vec![env.batch, env.seq], toks);
        let labels = if reg {
            TensorValue::f32(vec![env.batch], labels_f)
        } else {
            TensorValue::i32(vec![env.batch], labels_i)
        };
        trainer.step(&[tokens, labels])?;
    }

    // dev evaluation
    let mut logits = vec![0.0f32; task.dev.len() * n_out];
    let mut i = 0;
    while i < task.dev.len() {
        let (toks, _, _) = GlueTask::batch(&task.dev, i, env.batch, env.seq);
        let tokens = TensorValue::i32(vec![env.batch, env.seq], toks);
        let out = trainer.eval(&fwd_art, &[tokens])?;
        let data = out.as_f32();
        for row in 0..env.batch {
            if i + row < task.dev.len() {
                logits[(i + row) * n_out..(i + row + 1) * n_out]
                    .copy_from_slice(&data[row * n_out..(row + 1) * n_out]);
            }
        }
        i += env.batch;
    }
    let score = glue_score(task.metric, &logits, n_out, &task.dev);
    Ok((score, trainer.losses))
}

const GLUE_METHODS: [(QpeftInit, GradScale); 5] = [
    (QpeftInit::QLoRA, GradScale::None),
    (QpeftInit::LoftQ { iters: 5 }, GradScale::None),
    (QpeftInit::Qera, GradScale::None),
    (QpeftInit::LqLora { iters: 5 }, GradScale::None),
    (QpeftInit::Srr, GradScale::Fixed { gamma: 0.1 }),
];

fn glue_tasks_subset<'a>(ctx: &ExpCtx, env: &'a GlueEnv, all: bool) -> Vec<&'a GlueTask> {
    if ctx.quick {
        env.tasks.iter().take(2).collect()
    } else if all {
        env.tasks.iter().collect()
    } else {
        // metric-diverse subset for the ablations (budget)
        env.tasks
            .iter()
            .filter(|t| matches!(t.name, "MNLI-sim" | "RTE-sim" | "CoLA-sim" | "STSB-sim"))
            .collect()
    }
}

/// Table 3: GLUE-sim under 4/3/2-bit MXINT across QPEFT methods.
pub fn table3(ctx: &mut ExpCtx) -> Result<Vec<Table>> {
    let env = glue_env(ctx)?;
    let n_steps = steps(ctx, 40);
    let mut tables = vec![];
    let bit_settings: Vec<u32> = if ctx.quick { vec![2] } else { vec![4, 3, 2] };

    // 16-bit references (LoRA via identity backbone)
    {
        let tasks = glue_tasks_subset(ctx, &env, true);
        let mut t = Table::new(
            "Table 3 analog — 16-bit reference (LoRA, rank 8)",
            &{
                let mut h = vec!["method"];
                h.extend(tasks.iter().map(|t| t.name));
                h.push("avg");
                h
            },
        );
        let mut cells = vec!["LoRA(16b)".to_string()];
        let mut scores = vec![];
        for task in &tasks {
            let (s, _) = run_glue(ctx, &env, task, QpeftInit::LoRA, 4, GradScale::None, 1e-3, n_steps)?;
            scores.push(s);
            cells.push(f(s, 1));
        }
        cells.push(f(stats::mean(&scores), 1));
        t.row(cells);
        tables.push(t);
    }

    for bits in bit_settings {
        let rank = rank_for_bits(bits);
        let tasks = glue_tasks_subset(ctx, &env, true);
        let mut t = Table::new(
            &format!("Table 3 analog — GLUE-sim, {bits}-bit MXINT ({}.25b eff), rank {rank}", bits),
            &{
                let mut h = vec!["method"];
                h.extend(tasks.iter().map(|t| t.name));
                h.push("avg");
                h
            },
        );
        for (init, scale) in GLUE_METHODS {
            let mut cells = vec![init.label()];
            if init == QpeftInit::Srr {
                cells[0] = "SRR".into();
            }
            let mut scores = vec![];
            for task in &tasks {
                let (s, _) = run_glue(ctx, &env, task, init, bits, scale, 1e-3, n_steps)?;
                scores.push(s);
                cells.push(f(s, 1));
            }
            cells.push(f(stats::mean(&scores), 1));
            t.row(cells);
        }
        tables.push(t);
    }
    Ok(tables)
}

/// Table 6/17: γ ∈ {0, 0.1, 0.5, 1} vs SGP(α=5) on SRR-based QPEFT.
pub fn table6(ctx: &mut ExpCtx) -> Result<Vec<Table>> {
    gradient_scaling_grid(
        ctx,
        "Table 6/17 analog — SRR gradient scaling ablation",
        &[
            ("gamma=0", GradScale::Fixed { gamma: 0.0 }),
            ("gamma=1", GradScale::None),
            ("gamma=0.5", GradScale::Fixed { gamma: 0.5 }),
            ("gamma=0.1", GradScale::Fixed { gamma: 0.1 }),
            ("SGP(a=5)", GradScale::Sgp { alpha: 5.0 }),
        ],
        QpeftInit::Srr,
    )
}

/// Table 18: SGP α sensitivity.
pub fn table18(ctx: &mut ExpCtx) -> Result<Vec<Table>> {
    gradient_scaling_grid(
        ctx,
        "Table 18 analog — SGP alpha sensitivity (SRR-based)",
        &[
            ("SGP(a=0)", GradScale::Sgp { alpha: 0.0 }),
            ("SGP(a=5)", GradScale::Sgp { alpha: 5.0 }),
            ("SGP(a=10)", GradScale::Sgp { alpha: 10.0 }),
        ],
        QpeftInit::Srr,
    )
}

/// Table 19: SGP is not a generic add-on — QERA ± SGP.
pub fn table19(ctx: &mut ExpCtx) -> Result<Vec<Table>> {
    gradient_scaling_grid(
        ctx,
        "Table 19 analog — QERA with and without SGP",
        &[
            ("QERA", GradScale::None),
            // For QERA (k*=0) SGP has no preserved block to scale; the
            // paper applies it to the leading adapter directions instead —
            // we emulate by treating the top half of the rank as "preserved".
            ("QERA+SGP", GradScale::Sgp { alpha: 5.0 }),
        ],
        QpeftInit::Qera,
    )
}

fn gradient_scaling_grid(
    ctx: &mut ExpCtx,
    title: &str,
    variants: &[(&str, GradScale)],
    init: QpeftInit,
) -> Result<Vec<Table>> {
    let env = glue_env(ctx)?;
    let n_steps = steps(ctx, 40);
    let bit_settings: Vec<u32> = if ctx.quick { vec![2] } else { vec![4, 2] };
    let mut tables = vec![];
    for bits in bit_settings {
        let tasks = glue_tasks_subset(ctx, &env, false);
        let mut t = Table::new(
            &format!("{title} — {bits}-bit, rank {}", rank_for_bits(bits)),
            &{
                let mut h = vec!["scaling"];
                h.extend(tasks.iter().map(|t| t.name));
                h.push("avg");
                h
            },
        );
        for (label, scale) in variants {
            let mut cells = vec![label.to_string()];
            let mut scores = vec![];
            for task in &tasks {
                let patched_init = init;
                let (s, _) = run_glue_with_k_override(
                    ctx, &env, task, patched_init, bits, *scale, 1e-3, n_steps,
                )?;
                scores.push(s);
                cells.push(f(s, 1));
            }
            cells.push(f(stats::mean(&scores), 1));
            t.row(cells);
        }
        tables.push(t);
    }
    Ok(tables)
}

/// Like run_glue, but when the init has no preserved block (QERA) and SGP
/// is requested, mark the top half of the rank as preserved (Table 19's
/// "apply the same SGP procedure to QERA" protocol).
#[allow(clippy::too_many_arguments)]
fn run_glue_with_k_override(
    ctx: &mut ExpCtx,
    env: &GlueEnv,
    task: &GlueTask,
    init: QpeftInit,
    bits: u32,
    scale: GradScale,
    lr: f32,
    n_steps: usize,
) -> Result<(f64, Vec<f32>)> {
    if init == QpeftInit::Qera && matches!(scale, GradScale::Sgp { .. }) {
        // custom path: init then override k_star
        let fx = ctx.lm("tiny")?;
        let rank = rank_for_bits(bits);
        let reg = task.metric == Metric::PearsonSpearman;
        let (train_art, fwd_art) = if reg {
            (format!("qpeft_cls_train_reg_tiny_r{rank}"), format!("qpeft_cls_fwd_reg_tiny_r{rank}"))
        } else {
            (format!("qpeft_cls_train_tiny_r{rank}"), format!("qpeft_cls_fwd_tiny_r{rank}"))
        };
        let n_out = if reg { 1 } else { ctx.engine.manifest().cls_classes };
        let quant = QuantizerSpec::Mxint { bits, block: 32 };
        let mut state = init_qpeft(
            &fx.params, &fx.cfg, &fx.calib, quant, init, rank,
            head_init(&fx.cfg, n_out, 777), ctx.seed,
        );
        for a in &mut state.adapters {
            a.k_star = rank / 2;
        }
        let mut trainer = QpeftTrainer::new(&ctx.engine, &train_art, state, lr, scale);
        for step in 0..n_steps {
            let (toks, li, lf) = GlueTask::batch(&task.train, step * env.batch, env.batch, env.seq);
            let tokens = TensorValue::i32(vec![env.batch, env.seq], toks);
            let labels = if reg {
                TensorValue::f32(vec![env.batch], lf)
            } else {
                TensorValue::i32(vec![env.batch], li)
            };
            trainer.step(&[tokens, labels])?;
        }
        let mut logits = vec![0.0f32; task.dev.len() * n_out];
        let mut i = 0;
        while i < task.dev.len() {
            let (toks, _, _) = GlueTask::batch(&task.dev, i, env.batch, env.seq);
            let out = trainer.eval(&fwd_art, &[TensorValue::i32(vec![env.batch, env.seq], toks)])?;
            let data = out.as_f32();
            for row in 0..env.batch {
                if i + row < task.dev.len() {
                    logits[(i + row) * n_out..(i + row + 1) * n_out]
                        .copy_from_slice(&data[row * n_out..(row + 1) * n_out]);
                }
            }
            i += env.batch;
        }
        return Ok((glue_score(task.metric, &logits, n_out, &task.dev), trainer.losses));
    }
    run_glue(ctx, env, task, init, bits, scale, lr, n_steps)
}

/// Fig. 4/8: training-loss curves for three methods on the STSB and CoLA
/// analogs.
pub fn fig4(ctx: &mut ExpCtx) -> Result<Vec<Table>> {
    let env = glue_env(ctx)?;
    let n_steps = steps(ctx, 40);
    let methods = [
        ("QLoRA", QpeftInit::QLoRA, GradScale::None),
        ("QERA", QpeftInit::Qera, GradScale::None),
        ("SRR", QpeftInit::Srr, GradScale::Fixed { gamma: 0.1 }),
    ];
    let mut tables = vec![];
    for task_name in ["STSB-sim", "CoLA-sim"] {
        let task = env.tasks.iter().find(|t| t.name == task_name).unwrap().clone();
        let mut curves = vec![];
        for (label, init, scale) in methods {
            let (_, losses) = run_glue(ctx, &env, &task, init, 2, scale, 1e-3, n_steps)?;
            curves.push((label, losses));
        }
        let mut t = Table::new(
            &format!("Fig. 4 analog — training loss, {task_name} (2-bit, r=64)"),
            &["step", "QLoRA", "QERA", "SRR"],
        );
        let stride = (n_steps / 12).max(1);
        for s in (0..n_steps).step_by(stride) {
            t.row(vec![
                s.to_string(),
                f(curves[0].1[s] as f64, 4),
                f(curves[1].1[s] as f64, 4),
                f(curves[2].1[s] as f64, 4),
            ]);
        }
        tables.push(t);
    }
    Ok(tables)
}

// ---------------------------------------------------------------------------
// Table 4: CLM perplexity + GSM-sim accuracy on the LM trunk
// ---------------------------------------------------------------------------

/// Materialize a trained QPEFT state into dense LM params (W_hat = Qdeq +
/// L·R per linear; trained head) for evaluation via the standard
/// `lm_nll_*` / `lm_fwd_*` artifacts.
fn materialize_lm(state: &QpeftState, base: &Params, cfg: &crate::runtime::manifest::ModelCfg) -> Params {
    let mut out = base.clone();
    let order: Vec<String> = Params::param_order(cfg)
        .into_iter()
        .filter(|n| n != "head")
        .collect();
    for a in &state.adapters {
        let idx = order.iter().position(|n| n == &a.name).unwrap();
        let qdeq = state.frozen[idx].to_mat();
        out.set_mat(&a.name, &qdeq.add(&matmul(&a.l, &a.r)));
    }
    out.set_mat("head", &state.head);
    out
}

/// Table 4: CLM fine-tune PPL (r=8) + GSM-sim exact match (r=64).
pub fn table4(ctx: &mut ExpCtx) -> Result<Vec<Table>> {
    let fx = ctx.lm("tiny")?;
    let b = ctx.engine.manifest().lm_batch;
    let t_len = fx.cfg.seq_len;
    let gsm = GsmSim::generate(fx.cfg.vocab, t_len, 512, if ctx.quick { 32 } else { 96 }, 4242);
    let methods = [
        ("QLoRA", QpeftInit::QLoRA, GradScale::None),
        ("LoftQ", QpeftInit::LoftQ { iters: 5 }, GradScale::None),
        ("QERA", QpeftInit::Qera, GradScale::None),
        ("LQ-LoRA", QpeftInit::LqLora { iters: 5 }, GradScale::None),
        ("SRR", QpeftInit::Srr, GradScale::Fixed { gamma: 0.1 }),
    ];
    let bit_settings: Vec<u32> = if ctx.quick { vec![2] } else { vec![4, 2] };
    let mut tables = vec![];
    for bits in bit_settings {
        let mut t = Table::new(
            &format!("Table 4 analog — CLM PPL (r=8) + GSM-sim acc (r=64), {bits}-bit MXINT"),
            &["method", "CLM PPL", "GSM-sim acc (%)"],
        );
        for (label, init, scale) in methods {
            // --- CLM: rank 8 ---
            let clm_steps = steps(ctx, 60);
            let quant = QuantizerSpec::Mxint { bits, block: 32 };
            let lm_head = fx.params.get_mat("head")?;
            let state = init_qpeft(
                &fx.params, &fx.cfg, &fx.calib, quant, init, 8, lm_head.clone(), ctx.seed,
            );
            let mut trainer = QpeftTrainer::new(
                &ctx.engine, "qpeft_lm_train_tiny_r8", state, 5e-4, scale,
            );
            for step in 0..clm_steps {
                let batch = fx.corpus.train_batch(b, t_len, 10_000 + step);
                trainer.step(&[TensorValue::i32(vec![b, t_len], batch)])?;
            }
            let mat = materialize_lm(&trainer.state, &fx.params, &fx.cfg);
            let batches = ctx.ppl_batches("tiny")?;
            let ppl =
                perplexity(&ctx.engine, "lm_nll_tiny", &mat, &batches, b, t_len)?;

            // --- GSM: rank 64 ---
            let gsm_steps = steps(ctx, 90);
            let state = init_qpeft(
                &fx.params, &fx.cfg, &fx.calib, quant, init, 64, lm_head.clone(), ctx.seed,
            );
            let mut trainer = QpeftTrainer::new(
                &ctx.engine, "qpeft_lm_train_tiny_r64", state, 5e-4, scale,
            );
            for step in 0..gsm_steps {
                let batch = GsmSim::batch(&gsm.train, step * b, b);
                trainer.step(&[TensorValue::i32(vec![b, t_len], batch)])?;
            }
            let mat = materialize_lm(&trainer.state, &fx.params, &fx.cfg);
            let acc = gsm_exact_match(&ctx.engine, "lm_fwd_tiny", &mat, &gsm, &gsm.test, b)?;
            t.row(vec![label.into(), f(ppl, 2), f(acc, 1)]);
        }
        tables.push(t);
    }
    Ok(tables)
}
