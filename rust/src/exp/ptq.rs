//! PTQ experiments: Tables 1, 2, 5, 15, 16 and Figure 7.
//!
//! Every grid-shaped experiment drives the shared-work
//! [`run_sweep_factored`] engine: one pass over the model computes the
//! per-layer scalings / spectra / quantizations once and fans the whole
//! `(method, rank, scaling, seed)` grid out over the worker pool.
//! Bit-identity to the per-config `run_ptq` path holds at *matched*
//! prep rank (verified by `perf::sweep_bench`); cells below the grid's
//! maximum rank now truncate the grid-max factorization instead of
//! sketching at their own rank, so their recorded numbers shift
//! slightly versus the pre-sweep protocol (same algorithm, wider
//! randomized-SVD sketch).
//!
//! PPL grids score through the **fleet evaluator**
//! (`eval::fleet::fleet_perplexity`): outcomes sharing packed bases
//! (every rank/scaling variant of a `(quantizer, seed)` cell) forward in
//! one lock-step pass, decoding each base once per group per batch —
//! rust-native, no PJRT, no densified `W_hat` (speedup recorded by
//! `perf::evalbatch_bench` into `BENCH_evalbatch.json`). The BF16
//! reference rows use the same rust-native engine for consistency.

use anyhow::Result;

use crate::coordinator::{run_sweep, run_sweep_factored, Metrics, QuantizerSpec, SweepConfig};
use crate::data::zeroshot::ZeroShotTask;
use crate::eval::{fleet_perplexity, perplexity_native, zero_shot_accuracy};
use crate::linalg::effective_rank;
use crate::model::{ModelWeights, Params};
use crate::qer::Method;
use crate::runtime::Executor;
use crate::scaling::ScalingKind;
use crate::serve::FactoredModel;
use crate::util::bench::{f, pm, Table};
use crate::util::stats;

use super::fixtures::ExpCtx;

/// The ranks we sweep. The paper uses r ∈ {32, 64} on 4096-dim models
/// (r/d ≈ 0.8–1.6%); at our model widths the equivalent budgets are
/// r ∈ {4, 8} — recorded in EXPERIMENTS.md as the scaled setting.
pub const RANKS: [usize; 2] = [4, 8];

/// PPL-bearing experiments run on the *trained* models (tiny, small);
/// `base` has no train artifact by design and is used only for the
/// structure/selection analyses (fig5, table15) where training is not
/// required. See DESIGN.md §2.
pub fn models_for(_ctx: &ExpCtx) -> Vec<&'static str> {
    // PPL experiments run on the trained `tiny` model; `small` PPL runs
    // are provided by the e2e example, and `base` serves the
    // structure-only analyses (fig5/table15). Budget note in EXPERIMENTS.md.
    vec!["tiny"]
}

/// Rust-native PPL of one model (dense params or a factored outcome) on
/// the held-out batches — the same engine the fleet evaluator uses, so
/// reference rows and grid rows are comparable.
fn native_ppl(ctx: &mut ExpCtx, model: &str, weights: &dyn ModelWeights) -> Result<f64> {
    let fx = ctx.lm(model)?;
    let batches = ctx.ppl_batches(model)?;
    let b = ctx.engine.manifest().lm_batch;
    Ok(perplexity_native(weights, &fx.cfg, &batches, b, fx.cfg.seq_len))
}

/// Run a grid over `model` in one shared-work pass, then score every
/// outcome through the fleet evaluator in one lock-step batch — shared
/// packed bases are decoded once per group per eval batch instead of
/// once per outcome. Returns PPLs aligned with `configs`.
fn sweep_ppls(
    ctx: &mut ExpCtx,
    model: &str,
    configs: &[SweepConfig],
) -> Result<Vec<f64>> {
    let fx = ctx.lm(model)?;
    let batches = ctx.ppl_batches(model)?;
    let b = ctx.engine.manifest().lm_batch;
    let metrics = Metrics::new();
    let outs = run_sweep_factored(&fx.params, &fx.cfg, &fx.calib, configs, &metrics);
    let models: Vec<&FactoredModel> = outs.iter().map(|o| &o.model).collect();
    Ok(fleet_perplexity(&models, &fx.cfg, &batches, b, fx.cfg.seq_len)?)
}

fn mean_std(xs: &[f64]) -> (f64, f64) {
    (stats::mean(xs), stats::std_dev(xs))
}

/// Table 1: PPL under 3-bit MXINT for {LQER, QERA-approx, QERA-exact}
/// with and without SRR, across models and ranks.
pub fn table1(ctx: &mut ExpCtx) -> Result<Vec<Table>> {
    // Bit-width substitution (DESIGN.md §2): our models are 100–1000×
    // smaller than the paper's 7B+ checkpoints and far more robust to a
    // given relative weight error, so the damage-equivalent of the
    // paper's 3-bit setting is 2-bit MXINT here (3-bit leaves the PPL
    // delta within noise at this scale; measured in EXPERIMENTS.md).
    let quant = QuantizerSpec::Mxint { bits: 2, block: 32 };
    let scalings = [
        ("LQER", ScalingKind::DiagRms),
        ("QERA-approx", ScalingKind::DiagAbsMean),
        ("QERA-exact", ScalingKind::Exact),
    ];
    let seeds = ctx.srr_seeds();
    let mut tables = vec![];
    for model in models_for(ctx) {
        // one grid for the whole table: w-only + {base, SRR×seeds}×ranks
        let mut configs = vec![SweepConfig::new(quant, Method::WOnly, 0, ScalingKind::Identity)];
        // per (scaling, rank): (base config index, SRR config indices)
        let mut cells: Vec<Vec<(usize, Vec<usize>)>> = vec![];
        for (_, kind) in scalings {
            let mut per_rank = vec![];
            for rank in RANKS {
                let base = configs.len();
                configs.push(SweepConfig::new(quant, Method::Qer, rank, kind));
                let srr: Vec<usize> = seeds
                    .iter()
                    .map(|&s| {
                        configs.push(
                            SweepConfig::new(quant, Method::QerSrr, rank, kind).seeded(s),
                        );
                        configs.len() - 1
                    })
                    .collect();
                per_rank.push((base, srr));
            }
            cells.push(per_rank);
        }
        let ppls = sweep_ppls(ctx, model, &configs)?;

        let mut t = Table::new(
            &format!("Table 1 analog — PPL, 2-bit MXINT (2.25b eff; damage-equiv of paper 3-bit), model={model}"),
            &["method", "r=4", "r=8"],
        );
        let fx = ctx.lm(model)?;
        let bf16 = native_ppl(ctx, model, &fx.params)?;
        t.row(vec!["BF16".into(), f(bf16, 2), f(bf16, 2)]);
        t.row(vec!["w-only".into(), f(ppls[0], 2), f(ppls[0], 2)]);

        for ((label, _), per_rank) in scalings.iter().zip(&cells) {
            let mut base_cells = vec![];
            let mut srr_cells = vec![];
            for (base, srr) in per_rank {
                base_cells.push(f(ppls[*base], 2));
                let srr_ppls: Vec<f64> = srr.iter().map(|&i| ppls[i]).collect();
                let (m, s) = mean_std(&srr_ppls);
                srr_cells.push(pm(m, s, 2));
            }
            t.row(vec![label.to_string(), base_cells[0].clone(), base_cells[1].clone()]);
            t.row(vec![format!("{label} w/ SRR"), srr_cells[0].clone(), srr_cells[1].clone()]);
        }
        tables.push(t);
    }
    Ok(tables)
}

/// Table 2 / 13: zero-shot accuracy over the five probe tasks.
pub fn table2(ctx: &mut ExpCtx) -> Result<Vec<Table>> {
    let quant = QuantizerSpec::Mxint { bits: 2, block: 32 };
    let n_examples = if ctx.quick { 10 } else { 24 };
    let mut tables = vec![];
    let models: Vec<&str> = vec!["tiny"];
    for model in models {
        let fx = ctx.lm(model)?;
        let tasks = ZeroShotTask::all(&fx.corpus, fx.cfg.seq_len, n_examples, 77);
        let b = ctx.engine.manifest().lm_batch;
        let t_len = fx.cfg.seq_len;
        let artifact = format!("lm_nll_{model}");

        let mut t = Table::new(
            &format!("Table 2 analog — zero-shot accuracy (%), 2-bit MXINT r=8, model={model}"),
            &["method", "hellaswag-sim", "winogrande-sim", "boolq-sim", "mmlu-sim", "bbh-sim", "avg"],
        );
        let eval_model = |ctx: &ExpCtx, params: &Params| -> Result<Vec<f64>> {
            tasks
                .iter()
                .map(|task| {
                    zero_shot_accuracy(&ctx.engine, &artifact, params, task, b, t_len)
                        .map(|a| a * 100.0)
                })
                .collect()
        };
        let push = |name: &str, accs: Vec<f64>, t: &mut Table| {
            let avg = stats::mean(&accs);
            let mut cells = vec![name.to_string()];
            cells.extend(accs.iter().map(|&a| f(a, 1)));
            cells.push(f(avg, 1));
            t.row(cells);
        };

        // one shared-work pass for the three quantized rows
        let configs = vec![
            SweepConfig::new(quant, Method::WOnly, 0, ScalingKind::Identity)
                .labeled("w-only"),
            SweepConfig::new(quant, Method::Qer, 8, ScalingKind::Exact)
                .labeled("QERA-exact"),
            SweepConfig::new(quant, Method::QerSrr, 8, ScalingKind::Exact)
                .labeled("w/ SRR"),
        ];
        let metrics = Metrics::new();
        let outs = run_sweep(&fx.params, &fx.cfg, &fx.calib, &configs, &metrics);

        push("BF16", eval_model(ctx, &fx.params.clone())?, &mut t);
        for (c, out) in configs.iter().zip(&outs) {
            push(&c.label, eval_model(ctx, &out.params)?, &mut t);
        }
        tables.push(t);
    }
    Ok(tables)
}

/// Table 5: alternative quantizers (GPTQ 2-bit, QuIP#-sim 2-bit).
pub fn table5(ctx: &mut ExpCtx) -> Result<Vec<Table>> {
    let model = "tiny";
    let quants = [
        ("GPTQ(2-bit)", QuantizerSpec::Gptq { bits: 2, group: 128 }),
        ("QuIP#-sim(2-bit)", QuantizerSpec::QuipSharp { bits: 2 }),
    ];
    let scalings = [
        ("LQER", ScalingKind::DiagRms),
        ("QERA-approx", ScalingKind::DiagAbsMean),
        ("QERA-exact", ScalingKind::Exact),
    ];
    let seeds = ctx.srr_seeds();

    // one grid crossing both quantizers with every (scaling, ±SRR) cell:
    // the sweep shares scalings/spectra across quantizers too
    let mut configs = vec![];
    let mut wonly_idx = vec![];
    for (_, q) in quants {
        wonly_idx.push(configs.len());
        configs.push(SweepConfig::new(q, Method::WOnly, 0, ScalingKind::Identity));
    }
    // per scaling, per quantizer: (base idx, srr idxs)
    let mut cells: Vec<Vec<(usize, Vec<usize>)>> = vec![];
    for (_, kind) in scalings {
        let mut per_quant = vec![];
        for (_, q) in quants {
            let base = configs.len();
            configs.push(SweepConfig::new(q, Method::Qer, 8, kind));
            let srr: Vec<usize> = seeds
                .iter()
                .map(|&s| {
                    configs.push(SweepConfig::new(q, Method::QerSrr, 8, kind).seeded(s));
                    configs.len() - 1
                })
                .collect();
            per_quant.push((base, srr));
        }
        cells.push(per_quant);
    }
    let ppls = sweep_ppls(ctx, model, &configs)?;

    let mut t = Table::new(
        &format!("Table 5 analog — PPL under GPTQ / QuIP#-sim, r=8, model={model}"),
        &["method", "GPTQ(2-bit)", "QuIP#-sim(2-bit)"],
    );
    let fx = ctx.lm(model)?;
    let bf16 = native_ppl(ctx, model, &fx.params)?;
    t.row(vec!["BF16".into(), f(bf16, 2), f(bf16, 2)]);
    let mut wrow = vec!["w-only".into()];
    for &i in &wonly_idx {
        wrow.push(f(ppls[i], 2));
    }
    t.row(wrow);
    for ((label, _), per_quant) in scalings.iter().zip(&cells) {
        let mut base_row = vec![label.to_string()];
        let mut srr_row = vec![format!("{label} w/ SRR")];
        for (base, srr) in per_quant {
            base_row.push(f(ppls[*base], 2));
            let srr_ppls: Vec<f64> = srr.iter().map(|&i| ppls[i]).collect();
            let (m, s) = mean_std(&srr_ppls);
            srr_row.push(pm(m, s, 2));
        }
        t.row(base_row);
        t.row(srr_row);
    }
    Ok(vec![t])
}

/// Table 15: dimension-normalized effective rank of SW across scales.
pub fn table15(ctx: &mut ExpCtx) -> Result<Vec<Table>> {
    let models: Vec<&str> = if ctx.quick { vec!["tiny"] } else { vec!["tiny", "base"] };
    let projections = [("Key", "wk"), ("Output", "wo"), ("Down", "down")];
    let mut t = Table::new(
        "Table 15 analog — eRank(SW)/d by projection",
        &{
            let mut h = vec!["projection"];
            h.extend(models.iter().copied());
            h
        },
    );
    let mut rows: Vec<Vec<String>> =
        projections.iter().map(|(p, _)| vec![p.to_string()]).collect();
    for model in &models {
        let fx = ctx.lm(model)?;
        for (ri, (_, kind)) in projections.iter().enumerate() {
            // average over layers (layer 0 and mid) for stability
            let mut vals = vec![];
            for layer in [0, fx.cfg.n_layers / 2] {
                let name = format!("l{layer}.{kind}");
                let w = fx.params.get_mat(&name)?;
                let s = fx.calib.scaling_for(&name, ScalingKind::Exact);
                let sw = s.apply(&w);
                // full spectrum via the small-side Gram: σ_i = sqrt(λ_i(G))
                let gram = if sw.rows <= sw.cols {
                    crate::tensor::matmul_nt(&sw, &sw)
                } else {
                    crate::tensor::matmul_tn(&sw, &sw)
                };
                let (_, lam) = crate::linalg::eigh(&gram);
                let sv: Vec<f32> = lam.iter().map(|&l| l.max(0.0).sqrt()).collect();
                vals.push(effective_rank(&sv) / w.rows.min(w.cols) as f64);
            }
            rows[ri].push(f(stats::mean(&vals), 3));
        }
    }
    for r in rows {
        t.row(r);
    }
    Ok(vec![t])
}

/// Table 16: ODLRI-like fixed k=r/2 split vs adaptive SRR (same QERA-exact setting).
pub fn table16(ctx: &mut ExpCtx) -> Result<Vec<Table>> {
    let model = "tiny";
    let quant = QuantizerSpec::Mxint { bits: 2, block: 32 };
    let configs = vec![
        SweepConfig::new(quant, Method::FixedSplitHalf, 4, ScalingKind::Exact),
        SweepConfig::new(quant, Method::QerSrr, 4, ScalingKind::Exact),
    ];
    let ppls = sweep_ppls(ctx, model, &configs)?;
    let mut t = Table::new(
        &format!("Table 16 analog — fixed-split (ODLRI-like) vs SRR, PPL, r=4, model={model}"),
        &["method", "PPL"],
    );
    t.row(vec!["ODLRI-like (k=r/2)".into(), f(ppls[0], 2)]);
    t.row(vec!["SRR (k=k*)".into(), f(ppls[1], 2)]);
    Ok(vec![t])
}

/// Figure 7: layer-wise full reconstruction error under S = I.
pub fn fig7(ctx: &mut ExpCtx) -> Result<Vec<Table>> {
    let model = "tiny";
    let quant = QuantizerSpec::Mxint { bits: 2, block: 32 };
    let fx = ctx.lm(model)?;
    let metrics = Metrics::new();
    let configs = vec![
        SweepConfig::new(quant, Method::Qer, 8, ScalingKind::Identity),
        SweepConfig::new(quant, Method::QerSrr, 8, ScalingKind::Identity),
    ];
    // reports only — stay on the factored outcomes, no densified W_hat
    let outs = run_sweep_factored(&fx.params, &fx.cfg, &fx.calib, &configs, &metrics);
    let (qer, srr) = (&outs[0], &outs[1]);
    let mut t = Table::new(
        &format!("Fig. 7 analog — layer-wise |W-Q-LR|_F under ZeroQuant-V2 (S=I), r=8, model={model}"),
        &["layer", "QER", "SRR", "winner"],
    );
    let mut srr_wins = 0usize;
    for (a, b) in qer.reports.iter().zip(&srr.reports) {
        let win = if b.weight_err <= a.weight_err { "SRR" } else { "QER" };
        if win == "SRR" {
            srr_wins += 1;
        }
        t.row(vec![a.name.clone(), f(a.weight_err, 4), f(b.weight_err, 4), win.into()]);
    }
    t.row(vec![
        "TOTAL".into(),
        f(qer.total_weight_err(), 4),
        f(srr.total_weight_err(), 4),
        format!("SRR wins {srr_wins}/{}", qer.reports.len()),
    ]);
    Ok(vec![t])
}
