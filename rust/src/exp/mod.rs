//! Experiment registry: regenerates every table and figure of the paper
//! (DESIGN.md §5 maps exp ids → paper artifacts).
//!
//! Every experiment returns printable [`Table`]s shaped like the paper's
//! rows/series. `cargo bench` runs the full suite; individual experiments
//! run via `cargo bench -- --exp table1` or `srr bench table1`.
//!
//! `quick` mode shrinks workloads (fewer seeds/steps/batches) so the
//! suite smoke-runs in CI; the recorded EXPERIMENTS.md numbers come from
//! full mode. Experiments flagged `offline_ok` never execute a PJRT
//! artifact and also run without `artifacts/` (via [`ExpCtx::offline`]).

pub mod fixtures;
pub mod ptq;
pub mod rank;
pub mod qpeft_exp;
pub mod perf;

use anyhow::Result;

pub use fixtures::ExpCtx;

use crate::util::bench::Table;

pub type ExpFn = fn(&mut ExpCtx) -> Result<Vec<Table>>;

/// One registry row: experiment id, the paper artifact it regenerates,
/// the runner, and whether it can run without PJRT artifacts.
pub struct ExpEntry {
    pub id: &'static str,
    pub paper: &'static str,
    pub run: ExpFn,
    pub offline_ok: bool,
}

fn entry(id: &'static str, paper: &'static str, run: ExpFn) -> ExpEntry {
    ExpEntry { id, paper, run, offline_ok: false }
}

fn offline(id: &'static str, paper: &'static str, run: ExpFn) -> ExpEntry {
    ExpEntry { id, paper, run, offline_ok: true }
}

pub fn registry() -> Vec<ExpEntry> {
    vec![
        entry("table1", "Tab.1 WikiText2-PPL 3-bit MXINT, QER methods ± SRR", ptq::table1 as ExpFn),
        entry("table2", "Tab.2/13 zero-shot accuracy, QERA-exact ± SRR", ptq::table2),
        entry("table5", "Tab.5 GPTQ-3bit / QuIP#-2bit ± SRR", ptq::table5),
        entry("table15", "Tab.15 normalized eRank across scales", ptq::table15),
        entry("table16", "Tab.16 ODLRI-like fixed split vs SRR", ptq::table16),
        entry("fig7", "Fig.7 layer-wise |W-Q-LR| under S=I (ZeroQuant-V2)", ptq::fig7),
        entry("fig2", "Fig.2/6 reconstruction error vs surrogate over k", rank::fig2),
        entry("fig3", "Fig.3a singular spectrum of the packed adapter", rank::fig3),
        entry("fig5", "Fig.5 k* distribution by projection", rank::fig5),
        entry("table12", "Tab.12 k* stability across probe seeds", rank::table12),
        entry("table20", "Tab.20/21 Assumption 4.1/4.2 validation", rank::table20),
        entry("table3", "Tab.3 GLUE-sim QPEFT 4/3/2-bit", qpeft_exp::table3),
        entry("table4", "Tab.4 CLM-PPL + GSM-sim accuracy QPEFT", qpeft_exp::table4),
        entry("table6", "Tab.6/17 gamma / SGP gradient-scaling ablation", qpeft_exp::table6),
        entry("table18", "Tab.18 SGP alpha sensitivity", qpeft_exp::table18),
        entry("table19", "Tab.19 QERA ± SGP", qpeft_exp::table19),
        entry("fig4", "Fig.4/8/9 QPEFT training-loss curves", qpeft_exp::fig4),
        entry("table11", "Tab.11 computational overhead QER vs SRR", perf::table11),
        entry("perf", "§Perf kernel / pipeline / engine hot-path benches", perf::perf_suite),
        offline(
            "sweep",
            "§Perf sweep engine vs per-config run_ptq (writes BENCH_sweep.json)",
            perf::sweep_bench,
        ),
        offline(
            "serve",
            "§Perf factored QLR serving vs densified dense path (writes BENCH_serve.json)",
            perf::serve_bench,
        ),
        offline(
            "evalbatch",
            "§Perf fleet evaluator vs per-outcome PPL loops (writes BENCH_evalbatch.json)",
            perf::evalbatch_bench,
        ),
        offline(
            "shard",
            "§Perf multi-process shard plane: scaling + bit-identity vs in-process (writes BENCH_shard.json)",
            perf::shard_bench,
        ),
        offline(
            "spill",
            "§Perf out-of-core sweep store: bounded working set + kill-and-resume bit-identity (writes BENCH_spill.json)",
            perf::spill_bench,
        ),
        offline(
            "budget",
            "§Budget model-wide rank/bit allocator vs uniform baseline at equal bytes (writes BENCH_budget.json)",
            perf::budget_bench,
        ),
        offline(
            "serve_live",
            "§Perf continuous-batching daemon under live TCP load, serial-oracle bit-identity (writes BENCH_serve_live.json)",
            perf::serve_live_bench,
        ),
    ]
}

/// Run one experiment by id.
pub fn run(id: &str, ctx: &mut ExpCtx) -> Result<Vec<Table>> {
    for e in registry() {
        if e.id == id {
            return (e.run)(ctx);
        }
    }
    anyhow::bail!("unknown experiment '{id}' (see `srr bench --list`)")
}

/// Whether `id` is registered with `offline_ok` (no PJRT needed).
pub fn offline_ok(id: &str) -> bool {
    registry().iter().any(|e| e.id == id && e.offline_ok)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn registry_ids_are_unique_and_complete() {
        let reg = registry();
        let mut ids: Vec<&str> = reg.iter().map(|e| e.id).collect();
        let n = ids.len();
        ids.sort_unstable();
        ids.dedup();
        assert_eq!(ids.len(), n);
        for required in [
            "table1", "table2", "table3", "table4", "table5", "table6",
            "table11", "table12", "table15", "table16", "table18", "table19",
            "fig2", "fig3", "fig4", "fig5", "fig7", "perf", "sweep", "serve",
            "evalbatch", "shard", "serve_live", "budget", "spill",
        ] {
            assert!(ids.contains(&required), "missing {required}");
        }
    }

    #[test]
    fn sweep_is_offline_capable_and_ppl_experiments_are_not() {
        assert!(offline_ok("sweep"));
        assert!(offline_ok("serve"));
        assert!(offline_ok("evalbatch"));
        assert!(offline_ok("shard"));
        assert!(offline_ok("serve_live"));
        assert!(offline_ok("budget"));
        assert!(offline_ok("spill"));
        assert!(!offline_ok("table1"));
        assert!(!offline_ok("nonexistent"));
    }
}
