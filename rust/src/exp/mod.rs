//! Experiment registry: regenerates every table and figure of the paper
//! (DESIGN.md §5 maps exp ids → paper artifacts).
//!
//! Every experiment returns printable [`Table`]s shaped like the paper's
//! rows/series. `cargo bench` runs the full suite; individual experiments
//! run via `cargo bench -- --exp table1` or `srr bench table1`.
//!
//! `quick` mode shrinks workloads (fewer seeds/steps/batches) so the
//! suite smoke-runs in CI; the recorded EXPERIMENTS.md numbers come from
//! full mode.

pub mod fixtures;
pub mod ptq;
pub mod rank;
pub mod qpeft_exp;
pub mod perf;

use anyhow::Result;

pub use fixtures::ExpCtx;

use crate::util::bench::Table;

pub type ExpFn = fn(&mut ExpCtx) -> Result<Vec<Table>>;

/// (id, paper artifact, runner)
pub fn registry() -> Vec<(&'static str, &'static str, ExpFn)> {
    vec![
        ("table1", "Tab.1 WikiText2-PPL 3-bit MXINT, QER methods ± SRR", ptq::table1 as ExpFn),
        ("table2", "Tab.2/13 zero-shot accuracy, QERA-exact ± SRR", ptq::table2),
        ("table5", "Tab.5 GPTQ-3bit / QuIP#-2bit ± SRR", ptq::table5),
        ("table15", "Tab.15 normalized eRank across scales", ptq::table15),
        ("table16", "Tab.16 ODLRI-like fixed split vs SRR", ptq::table16),
        ("fig7", "Fig.7 layer-wise |W-Q-LR| under S=I (ZeroQuant-V2)", ptq::fig7),
        ("fig2", "Fig.2/6 reconstruction error vs surrogate over k", rank::fig2),
        ("fig3", "Fig.3a singular spectrum of the packed adapter", rank::fig3),
        ("fig5", "Fig.5 k* distribution by projection", rank::fig5),
        ("table12", "Tab.12 k* stability across probe seeds", rank::table12),
        ("table20", "Tab.20/21 Assumption 4.1/4.2 validation", rank::table20),
        ("table3", "Tab.3 GLUE-sim QPEFT 4/3/2-bit", qpeft_exp::table3),
        ("table4", "Tab.4 CLM-PPL + GSM-sim accuracy QPEFT", qpeft_exp::table4),
        ("table6", "Tab.6/17 gamma / SGP gradient-scaling ablation", qpeft_exp::table6),
        ("table18", "Tab.18 SGP alpha sensitivity", qpeft_exp::table18),
        ("table19", "Tab.19 QERA ± SGP", qpeft_exp::table19),
        ("fig4", "Fig.4/8/9 QPEFT training-loss curves", qpeft_exp::fig4),
        ("table11", "Tab.11 computational overhead QER vs SRR", perf::table11),
        ("perf", "§Perf kernel / pipeline / engine hot-path benches", perf::perf_suite),
    ]
}

/// Run one experiment by id.
pub fn run(id: &str, ctx: &mut ExpCtx) -> Result<Vec<Table>> {
    for (name, _, f) in registry() {
        if name == id {
            return f(ctx);
        }
    }
    anyhow::bail!("unknown experiment '{id}' (see `srr bench --list`)")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn registry_ids_are_unique_and_complete() {
        let reg = registry();
        let mut ids: Vec<&str> = reg.iter().map(|(n, _, _)| *n).collect();
        let n = ids.len();
        ids.sort_unstable();
        ids.dedup();
        assert_eq!(ids.len(), n);
        for required in [
            "table1", "table2", "table3", "table4", "table5", "table6",
            "table11", "table12", "table15", "table16", "table18", "table19",
            "fig2", "fig3", "fig4", "fig5", "fig7", "perf",
        ] {
            assert!(ids.contains(&required), "missing {required}");
        }
    }
}
