//! Performance experiments: Table 11 (coordinator overhead accounting),
//! the §Perf hot-path benches (kernel parity timings, PJRT engine
//! throughput, linalg primitives, fused-QLR serving path), the sweep
//! engine's shared-work speedup measurement (`BENCH_sweep.json`), the
//! factored-vs-dense serving comparison (`BENCH_serve.json`), and the
//! fleet-vs-per-outcome eval comparison (`BENCH_evalbatch.json`).

use std::sync::Arc;
use std::time::Instant;

use anyhow::Result;

use crate::coordinator::{
    allocate, fleet_perplexity_sharded, run_ptq, run_ptq_factored, run_sweep,
    run_sweep_factored, run_sweep_spilled, uniform_plan, BudgetSpec, FactoredOutcome, Metrics,
    QuantizerSpec, ShardOptions, ShardSession, ShardedSweepRunner, SpillOptions, SpillStore,
    SweepConfig, SweepRunner,
};
use crate::eval::{fleet_footprint, fleet_perplexity, perplexity_native, perplexity_native_masked};
use crate::linalg::{eigh, jacobi_svd, randomized_svd};
use crate::qer::{reconstruct, Method, QerConfig};
use crate::quant::{MxintQuantizer, QuantCtx, Quantizer, UniformQuantizer};
use crate::runtime::{Executor, TensorValue};
use crate::scaling::{Scaling, ScalingKind};
use crate::serve::{packed_matmul_scalar_ref, FactoredModel, LinearOp, QuantBase};
use crate::tensor::{matmul, matmul_nt, matmul_tn, Mat};
use crate::util::bench::{self, f, time_fn, Table};
use crate::util::json::Json;
use crate::util::Rng;

use super::fixtures::ExpCtx;

/// Table 11: wall-clock of scaling vs reconstruction, QER vs SRR, plus
/// the sweep engine's shared-stage split.
pub fn table11(ctx: &mut ExpCtx) -> Result<Vec<Table>> {
    let model = "tiny";
    let fx = ctx.lm(model)?;
    let quant = QuantizerSpec::Mxint { bits: 3, block: 32 };

    // time the scaling-matrix stage separately (the dominant cost per the
    // paper); the calibration cache is cold only on the first pass.
    let t_scale = time_fn("scaling", 0, 1, || {
        for name in crate::model::Params::linear_names(&fx.cfg) {
            let _ = fx.calib.scaling_for(&name, ScalingKind::Exact);
        }
    });

    let run = |method: Method| {
        let metrics = Metrics::new();
        let mut cfg = QerConfig::new(method, 8, ScalingKind::Exact);
        cfg.seed = 1;
        let t = time_fn("ptq", 0, 1, || {
            run_ptq(&fx.params, &fx.cfg, &fx.calib, quant, &cfg, &metrics)
        });
        t.mean_ns / 1e9
    };
    let qer_secs = run(Method::Qer);
    let srr_secs = run(Method::QerSrr);
    let scale_secs = t_scale.mean_ns / 1e9;

    let mut t = Table::new(
        &format!("Table 11 analog — stage wall-clock (seconds), model={model}, QERA-exact r=8"),
        &["stage", "QER", "SRR", "ratio"],
    );
    t.row(vec!["scaling (eigh, cached after)".into(), f(scale_secs, 3), f(scale_secs, 3), "x1.00".into()]);
    t.row(vec![
        "quantize+reconstruct".into(),
        f(qer_secs, 3),
        f(srr_secs, 3),
        format!("x{:.2}", srr_secs / qer_secs.max(1e-9)),
    ]);
    let total_q = scale_secs + qer_secs;
    let total_s = scale_secs + srr_secs;
    t.row(vec![
        "full pipeline".into(),
        f(total_q, 3),
        f(total_s, 3),
        format!("x{:.2}", total_s / total_q.max(1e-9)),
    ]);

    // Table 11b: where a shared-work sweep spends its time. Cold cache so
    // the scaling/Hessian/spectra preparation is actually visible.
    let configs = vec![
        SweepConfig::new(quant, Method::Qer, 8, ScalingKind::Exact),
        SweepConfig::new(quant, Method::QerSrr, 8, ScalingKind::Exact),
        SweepConfig::new(quant, Method::QerSrr, 4, ScalingKind::Exact),
    ];
    let metrics = Metrics::new();
    let cold = fx.calib.cold_copy();
    let t0 = Instant::now();
    let _ = run_sweep(&fx.params, &fx.cfg, &cold, &configs, &metrics);
    let wall = t0.elapsed().as_secs_f64();
    // stage rows are CPU-seconds summed across worker threads (they can
    // exceed wall-clock on multicore); shares are of total stage CPU
    let stages = [
        ("prepare: scalings", "sweep.scaling_cpu_secs"),
        ("prepare: Hessians", "sweep.hessian_cpu_secs"),
        ("prepare: k=0 quantize", "sweep.qdeq_cpu_secs"),
        ("prepare: spectra (SW/SE SVDs)", "sweep.spectra_cpu_secs"),
        ("shared residual SVDs", "sweep.resid_cpu_secs"),
        ("per-config fan-out", "sweep.reconstruct_cpu_secs"),
    ];
    let total_cpu: f64 = stages.iter().map(|(_, k)| metrics.get(k)).sum();
    let mut tb = Table::new(
        &format!(
            "Table 11b — sweep stage split (CPU-seconds across workers), {} configs, model={model}",
            configs.len()
        ),
        &["stage", "cpu secs", "share of stage cpu"],
    );
    for (label, key) in stages {
        let v = metrics.get(key);
        tb.row(vec![label.into(), f(v, 3), format!("{:.0}%", 100.0 * v / total_cpu.max(1e-9))]);
    }
    tb.row(vec!["total stage cpu".into(), f(total_cpu, 3), "100%".into()]);
    tb.row(vec!["wall-clock (parallel)".into(), f(wall, 3), String::new()]);
    Ok(vec![t, tb])
}

/// §Perf sweep: the shared-work engine against the per-config `run_ptq`
/// loop on the quick-mode Table 1 grid — byte-identical results required,
/// wall-clock recorded into `BENCH_sweep.json`.
pub fn sweep_bench(ctx: &mut ExpCtx) -> Result<Vec<Table>> {
    let model = "tiny";
    let fx = ctx.lm(model)?;
    let quant = QuantizerSpec::Mxint { bits: 2, block: 32 };
    let scalings = [ScalingKind::DiagRms, ScalingKind::DiagAbsMean, ScalingKind::Exact];

    // the quick-mode Table 1 grid: w-only + {QER, QER+SRR} × scalings × ranks
    let mut configs = vec![SweepConfig::new(quant, Method::WOnly, 0, ScalingKind::Identity)];
    for kind in scalings {
        for rank in super::ptq::RANKS {
            configs.push(SweepConfig::new(quant, Method::Qer, rank, kind));
            configs.push(SweepConfig::new(quant, Method::QerSrr, rank, kind));
        }
    }
    let prep_rank = SweepRunner::prep_rank(&configs);
    // prep_rank: None = the natural per-config cost (what a real `srr
    // ptq` invocation pays) — used for the timed baselines; Some(grid
    // max) = the bit-identity contract — used for the untimed
    // equivalence pass below. Keeping them separate keeps the recorded
    // speedup honest.
    let qcfg_for = |c: &SweepConfig, prep: Option<usize>| {
        let mut qcfg = QerConfig::new(c.method, c.rank, c.scaling);
        qcfg.seed = c.seed;
        qcfg.prep_rank = prep;
        qcfg
    };

    // shared-work sweep, cold cache (scaling builds included in the time)
    let metrics = Metrics::new();
    let sweep_calib = fx.calib.cold_copy();
    let t0 = Instant::now();
    let sweep_outs = run_sweep(&fx.params, &fx.cfg, &sweep_calib, &configs, &metrics);
    let sweep_secs = t0.elapsed().as_secs_f64();

    // baseline 1: independent per-config run_ptq calls, each from a cold
    // scaling cache — the pre-sweep exp/ptq.rs protocol (and what every
    // `srr ptq` CLI invocation pays)
    let base_metrics = Metrics::new();
    let t1 = Instant::now();
    for c in &configs {
        let calib = fx.calib.cold_copy();
        let _ = run_ptq(&fx.params, &fx.cfg, &calib, c.quantizer, &qcfg_for(c, None), &base_metrics);
    }
    let cold_secs = t1.elapsed().as_secs_f64();

    // baseline 2: the same loop with the scaling memo shared (what the
    // old in-process experiment loop amortized already)
    let warm_calib = fx.calib.cold_copy();
    let t2 = Instant::now();
    for c in &configs {
        let _ = run_ptq(&fx.params, &fx.cfg, &warm_calib, c.quantizer, &qcfg_for(c, None), &base_metrics);
    }
    let warm_secs = t2.elapsed().as_secs_f64();

    // acceptance: byte-identical per-layer decompositions against the
    // per-config path under the sweep's prep rank (untimed; reuses the
    // warm scaling memo — scalings are deterministic either way)
    let mut identical = true;
    for (c, sweep_out) in configs.iter().zip(&sweep_outs) {
        let solo = run_ptq(
            &fx.params,
            &fx.cfg,
            &warm_calib,
            c.quantizer,
            &qcfg_for(c, Some(prep_rank)),
            &base_metrics,
        );
        for ((n1, r1), (n2, r2)) in sweep_out.results.iter().zip(&solo.results) {
            if n1 != n2
                || r1.qdeq != r2.qdeq
                || r1.l != r2.l
                || r1.r != r2.r
                || r1.k_star != r2.k_star
            {
                identical = false;
            }
        }
    }
    anyhow::ensure!(identical, "sweep results diverge from per-config run_ptq");

    let speedup_cold = cold_secs / sweep_secs.max(1e-9);
    let speedup_warm = warm_secs / sweep_secs.max(1e-9);

    let stage = Json::obj(
        metrics
            .snapshot()
            .iter()
            .filter(|(k, _)| k.starts_with("sweep."))
            .map(|(k, v)| (k.as_str(), Json::num(*v)))
            .collect::<Vec<_>>(),
    );
    let record = Json::obj(vec![
        ("model", Json::str(model)),
        ("quick", Json::Bool(ctx.quick)),
        ("grid", Json::arr(configs.iter().map(|c| Json::str(c.label.clone())).collect())),
        ("prep_rank", Json::num(prep_rank as f64)),
        ("sweep_secs", Json::num(sweep_secs)),
        ("per_config_cold_secs", Json::num(cold_secs)),
        ("per_config_warm_secs", Json::num(warm_secs)),
        ("speedup_cold", Json::num(speedup_cold)),
        ("speedup_warm", Json::num(speedup_warm)),
        ("identical", Json::Bool(identical)),
        ("stage_secs", stage),
    ]);
    bench::write_json("BENCH_sweep.json", &record)?;

    let mut t = Table::new(
        &format!(
            "§Perf sweep — SweepRunner vs per-config run_ptq ({} configs, model={model}, recorded in BENCH_sweep.json)",
            configs.len()
        ),
        &["path", "secs", "speedup"],
    );
    t.row(vec!["per-config loop (cold scaling cache)".into(), f(cold_secs, 3), format!("x{speedup_cold:.2}")]);
    t.row(vec!["per-config loop (warm scaling cache)".into(), f(warm_secs, 3), format!("x{speedup_warm:.2}")]);
    t.row(vec!["SweepRunner (shared-work)".into(), f(sweep_secs, 3), "x1.00 (ref)".into()]);
    t.row(vec![
        "byte-identical results".into(),
        if identical { "yes".into() } else { "NO".into() },
        String::new(),
    ]);
    Ok(vec![t])
}

/// §Perf serve: the factored QLR serving path (`serve::LinearOp`)
/// against the densified dense path, recorded into `BENCH_serve.json`.
///
/// Four sections:
/// 1. **equivalence gate** — factored forward vs densified `W_hat`
///    forward within 1e-5 relative error for the uniform, MXINT and
///    GPTQ quantizer families at ranks {0, 16, 64} (hard failure);
/// 2. **model footprint** — `run_ptq_factored` on the tiny model: bytes
///    of the factored linears vs their dense form, plus rust-native PPL
///    through the factored model (no PJRT, no densify) cross-checked
///    against the densified params;
/// 3. **throughput** — matvec and batch-8 matmul through a large layer,
///    dense GEMM vs streamed packed decode;
/// 4. **decode kernels + roofline** — the block unpack paths vs the
///    retained scalar bit-cursor reference on a 4-bit uniform layer:
///    `kernel_bit_identical` (decode / axpy / batched matmul,
///    bit-for-bit — hard failure, CI-gated) and the batch-1 matvec
///    speedup, plus roofline accounting (bytes decoded, FLOPs, achieved
///    GB/s and GFLOP/s against a measured streaming-read ceiling).
pub fn serve_bench(ctx: &mut ExpCtx) -> Result<Vec<Table>> {
    let mut tables = vec![];
    let iters = if ctx.quick { 3 } else { 10 };
    let mut rng = Rng::new(0x5EE5);

    // --- 1. factored-vs-dense equivalence over the quantizer families ---
    let (m, n) = (192usize, 256usize);
    let w = Mat::randn(m, n, 1.0, &mut rng);
    let xb = Mat::randn(8, m, 1.0, &mut rng);
    let gram = {
        let xcal = Mat::randn(2 * m, m, 1.0, &mut rng);
        matmul_tn(&xcal, &xcal).scale(1.0 / (2 * m) as f32)
    };
    let quants = [
        QuantizerSpec::Uniform { bits: 4, group: 64, symmetric: false },
        QuantizerSpec::Mxint { bits: 3, block: 32 },
        QuantizerSpec::Gptq { bits: 3, group: 64 },
    ];
    let mut equiv = Table::new(
        "§Perf serve — factored QLR vs densified W_hat forward (rel err, 8x192 batch)",
        &["quantizer", "rank", "rel err", "packed bits/weight"],
    );
    let mut equiv_max = 0.0f64;
    let mut equiv_rows = vec![];
    for spec in quants {
        for rank in [0usize, 16, 64] {
            let method = if rank == 0 { Method::WOnly } else { Method::Qer };
            let ctxq = QuantCtx {
                hessian: if spec.needs_hessian() { Some(gram.clone()) } else { None },
                seed: 1,
            };
            let mut cfg = QerConfig::new(method, rank, ScalingKind::Identity);
            cfg.seed = 1;
            let res = reconstruct(&w, spec.build().as_ref(), &Scaling::Identity, &ctxq, &cfg);
            let what = res.reconstruct();
            let op = res.into_factored();
            anyhow::ensure!(
                matches!(&op, LinearOp::FactoredQlr { base: QuantBase::Packed(_), .. }),
                "{}: expected a packed base",
                spec.label()
            );
            let bits = match &op {
                LinearOp::FactoredQlr { base: QuantBase::Packed(p), .. } => p.effective_bits(),
                _ => unreachable!(),
            };
            let dense_y = matmul(&xb, &what);
            let fact_y = op.matmul(&xb);
            let rel = fact_y.sub(&dense_y).frob() / dense_y.frob().max(1e-12);
            anyhow::ensure!(
                rel < 1e-5,
                "{} r={rank}: factored forward diverges (rel {rel})",
                spec.label()
            );
            equiv_max = equiv_max.max(rel);
            equiv.row(vec![spec.label(), rank.to_string(), format!("{rel:.2e}"), f(bits, 2)]);
            equiv_rows.push(Json::obj(vec![
                ("quantizer", Json::str(spec.label())),
                ("rank", Json::num(rank as f64)),
                ("rel_err", Json::num(rel)),
            ]));
        }
    }
    tables.push(equiv);

    // --- 2. whole-model footprint + rust-native factored PPL ------------
    let fx = ctx.lm("tiny")?;
    let quant = QuantizerSpec::Mxint { bits: 2, block: 32 };
    let metrics = Metrics::new();
    let qcfg = QerConfig::new(Method::QerSrr, 16, ScalingKind::DiagRms);
    let fo = run_ptq_factored(&fx.params, &fx.cfg, &fx.calib, quant, &qcfg, &metrics);
    let model_fact = fo.model.linear_bytes();
    let model_dense = fo.model.dense_linear_bytes();
    let model_x = model_dense as f64 / model_fact.max(1) as f64;
    anyhow::ensure!(
        model_x > 2.0,
        "factored model should be well under half the dense bytes, got x{model_x:.2}"
    );
    let b = ctx.engine.manifest().lm_batch;
    let t_len = fx.cfg.seq_len;
    let batches = ctx.ppl_batches("tiny")?;
    let ppl_fact = perplexity_native(&fo.model, &fx.cfg, &batches, b, t_len);
    let densified = fo.model.densified_params();
    let ppl_dense = perplexity_native(&densified, &fx.cfg, &batches, b, t_len);
    anyhow::ensure!(
        (ppl_fact / ppl_dense - 1.0).abs() < 1e-3,
        "factored PPL {ppl_fact} vs densified {ppl_dense}"
    );

    // --- 3. serving throughput: dense GEMM vs streamed packed decode ----
    // full mode sizes the layer well past LLC so the dense path pays DRAM
    // for 16x the bytes the packed codes occupy
    let big = if ctx.quick { 1024 } else { 4096 };
    let rank = 64usize;
    let wbig = Mat::randn(big, big, 1.0, &mut rng);
    let q2 = MxintQuantizer::new(2, 32);
    let (qdeq, packed) = q2.quantize_coded(&wbig, &QuantCtx::default());
    let packed = Arc::new(packed.expect("mxint packs"));
    let packed_bits = packed.effective_bits();
    let l = Mat::randn(big, rank, 0.05, &mut rng);
    let r = Mat::randn(rank, big, 0.05, &mut rng);
    let dense_op = LinearOp::Dense(qdeq.add(&matmul(&l, &r)));
    let fact_op = LinearOp::FactoredQlr { base: QuantBase::Packed(packed.clone()), l, r };
    let bytes_dense = dense_op.bytes();
    let bytes_fact = fact_op.bytes();
    anyhow::ensure!(bytes_fact < bytes_dense, "packed layer must be smaller");

    let x1: Vec<f32> = {
        let mut v = vec![0.0f32; big];
        rng.fill_normal(&mut v, 1.0);
        v
    };
    let x8 = Mat::randn(8, big, 1.0, &mut rng);
    let t_d1 = time_fn("dense matvec", 1, iters, || dense_op.matvec(&x1));
    let t_f1 = time_fn("factored matvec", 1, iters, || fact_op.matvec(&x1));
    let t_d8 = time_fn("dense matmul b8", 1, iters, || dense_op.matmul(&x8));
    let t_f8 = time_fn("factored matmul b8", 1, iters, || fact_op.matmul(&x8));
    let tps = |t: &bench::Timing, toks: f64| toks / (t.mean_ns / 1e9);
    let sp1 = t_d1.mean_ns / t_f1.mean_ns;
    let sp8 = t_d8.mean_ns / t_f8.mean_ns;

    let mut t = Table::new(
        &format!(
            "§Perf serve — {big}x{big} r{rank} layer, mxint2 ({packed_bits:.2} bits/w packed), \
             recorded in BENCH_serve.json"
        ),
        &["path", "bytes", "matvec ms (tok/s)", "b8 ms (tok/s)", "speedup mv / b8"],
    );
    t.row(vec![
        "dense W_hat".into(),
        bytes_dense.to_string(),
        format!("{} ({:.0})", f(t_d1.mean_ms(), 3), tps(&t_d1, 1.0)),
        format!("{} ({:.0})", f(t_d8.mean_ms(), 3), tps(&t_d8, 8.0)),
        "x1.00 (ref)".into(),
    ]);
    t.row(vec![
        "factored Q + L·R (packed)".into(),
        bytes_fact.to_string(),
        format!("{} ({:.0})", f(t_f1.mean_ms(), 3), tps(&t_f1, 1.0)),
        format!("{} ({:.0})", f(t_f8.mean_ms(), 3), tps(&t_f8, 8.0)),
        format!("x{sp1:.2} / x{sp8:.2}"),
    ]);
    t.row(vec![
        "model (tiny, mxint2 r16 SRR)".into(),
        format!("{model_fact} vs {model_dense}"),
        format!("x{model_x:.2} smaller"),
        format!("ppl {ppl_fact:.2} vs {ppl_dense:.2}"),
        String::new(),
    ]);
    tables.push(t);

    // --- 4. decode kernels: block unpack vs scalar reference + roofline -
    // the ISSUE-7 acceptance layer: 4-bit uniform (the width the
    // monomorphized `unpack_words::<4, 16>` path serves) with rank-64
    // adapters, batch-1 — tokens/sec through the block kernels vs the
    // retained scalar bit-cursor path, measured rather than asserted
    let w4 = Mat::randn(big, big, 1.0, &mut rng);
    let q4 = UniformQuantizer::new(4, 64, false);
    let (_, packed4) = q4.quantize_coded(&w4, &QuantCtx::default());
    let packed4 = Arc::new(packed4.expect("uniform packs"));
    let l4 = Mat::randn(big, rank, 0.05, &mut rng);
    let r4 = Mat::randn(rank, big, 0.05, &mut rng);
    let op4 = LinearOp::FactoredQlr { base: QuantBase::Packed(packed4.clone()), l: l4, r: r4 };

    // kernel_bit_identical: block decode/axpy and the cache-blocked
    // batched matmul vs the scalar reference, bit-for-bit, with spans
    // landing mid-group and mid-word on both the mxint2 and uniform4
    // layers. (The *fused* batch-1 matvec is excluded by design —
    // folding the correction into the base pass reorders f32 sums; its
    // 1e-5 agreement is pinned by the serve property suite.)
    let mut kernel_bit_identical = true;
    for p in [&*packed, &*packed4] {
        for i in [0usize, 1, big / 2, big - 1] {
            for (j0, j1) in [(0usize, big), (1, 66), (63, 129), (big - 131, big - 2)] {
                let width = j1 - j0;
                let mut fast = vec![0.0f32; width];
                let mut slow = vec![0.0f32; width];
                p.decode_span_into(i, j0, j1, &mut fast);
                p.decode_span_into_scalar(i, j0, j1, &mut slow);
                let mut acc_f = vec![0.0f32; width];
                rng.fill_normal(&mut acc_f, 1.0);
                let mut acc_s = acc_f.clone();
                p.axpy_span(i, j0, j1, 0.73, &mut acc_f);
                p.axpy_span_scalar(i, j0, j1, 0.73, &mut acc_s);
                kernel_bit_identical &= fast
                    .iter()
                    .zip(&slow)
                    .chain(acc_f.iter().zip(&acc_s))
                    .all(|(a, b)| a.to_bits() == b.to_bits());
            }
        }
    }
    // batched path through a rank-0 op, so the comparison isolates the
    // tiled base kernels from the (row-order-preserving) correction
    let op4_r0 = LinearOp::FactoredQlr {
        base: QuantBase::Packed(packed4.clone()),
        l: Mat::zeros(big, 0),
        r: Mat::zeros(0, big),
    };
    let y_blocked = op4_r0.matmul(&x8);
    let y_scalar = packed_matmul_scalar_ref(&packed4, &x8);
    kernel_bit_identical &= y_blocked
        .data
        .iter()
        .zip(&y_scalar.data)
        .all(|(a, b)| a.to_bits() == b.to_bits());

    let x4: Vec<f32> = {
        let mut v = vec![0.0f32; big];
        rng.fill_normal(&mut v, 1.0);
        v
    };
    let t_k_scalar =
        time_fn("matvec, scalar bit-cursor ref", 1, iters, || op4.matvec_scalar_ref(&x4));
    let t_k_block = time_fn("matvec, block kernels", 1, iters, || op4.matvec(&x4));
    let kernel_speedup = t_k_scalar.mean_ns / t_k_block.mean_ns;

    // roofline: what one token must move vs what it computes. The
    // factored matvec reads the packed payload (codes + group side data)
    // and both adapter factors exactly once; activations are noise at
    // this size. FLOPs: 2mn base + 2(mr + rn) correction.
    let decode_bytes = packed4.bytes() as f64;
    let adapter_bytes = (op4.bytes() - packed4.bytes()) as f64;
    let flops = 2.0 * (big * big) as f64 + 4.0 * (big * rank) as f64;
    let gbps = |t: &bench::Timing| (decode_bytes + adapter_bytes) / t.mean_ns;
    let gflops = |t: &bench::Timing| flops / t.mean_ns;
    let ceiling_gbps = bench::stream_read_gbps(if ctx.quick { 1 } else { 3 });
    let achieved_gbps = gbps(&t_k_block);
    let achieved_gflops = gflops(&t_k_block);

    let mut t4 = Table::new(
        &format!(
            "§Perf serve decode kernels — {big}x{big} r{rank} uniform4 layer, batch-1 \
             (measured stream-read ceiling {ceiling_gbps:.1} GB/s, recorded in BENCH_serve.json)"
        ),
        &["path", "ms/token", "tok/s", "GB/s", "GFLOP/s"],
    );
    for tm in [&t_k_scalar, &t_k_block] {
        t4.row(vec![
            tm.name.clone(),
            f(tm.mean_ms(), 3),
            f(1e9 / tm.mean_ns, 0),
            f(gbps(tm), 2),
            f(gflops(tm), 2),
        ]);
    }
    t4.row(vec![
        "block vs scalar".into(),
        format!("x{kernel_speedup:.2}"),
        format!("bit-identical: {kernel_bit_identical}"),
        format!("{:.0}% of ceiling", 100.0 * achieved_gbps / ceiling_gbps.max(1e-9)),
        String::new(),
    ]);
    tables.push(t4);

    let record = Json::obj(vec![
        ("quick", Json::Bool(ctx.quick)),
        ("equivalence_max_rel_err", Json::num(equiv_max)),
        ("equivalence", Json::arr(equiv_rows)),
        ("layer_dim", Json::num(big as f64)),
        ("layer_rank", Json::num(rank as f64)),
        ("layer_packed_bits_per_weight", Json::num(packed_bits)),
        ("bytes_dense", Json::num(bytes_dense as f64)),
        ("bytes_factored", Json::num(bytes_fact as f64)),
        ("bytes_compression_x", Json::num(bytes_dense as f64 / bytes_fact.max(1) as f64)),
        ("matvec_ms_dense", Json::num(t_d1.mean_ms())),
        ("matvec_ms_factored", Json::num(t_f1.mean_ms())),
        ("matvec_speedup_x", Json::num(sp1)),
        ("matvec_tokens_per_sec_dense", Json::num(tps(&t_d1, 1.0))),
        ("matvec_tokens_per_sec_factored", Json::num(tps(&t_f1, 1.0))),
        ("matmul8_ms_dense", Json::num(t_d8.mean_ms())),
        ("matmul8_ms_factored", Json::num(t_f8.mean_ms())),
        ("matmul8_speedup_x", Json::num(sp8)),
        ("model_bytes_dense", Json::num(model_dense as f64)),
        ("model_bytes_factored", Json::num(model_fact as f64)),
        ("model_compression_x", Json::num(model_x)),
        ("model_ppl_factored", Json::num(ppl_fact)),
        ("model_ppl_densified", Json::num(ppl_dense)),
        // decode-kernel section (4): equivalence + speedup + roofline.
        // kernel_bit_identical is asserted *after* the record is written
        // so a divergence still lands in the file for the CI gate.
        ("kernel_bit_identical", Json::Bool(kernel_bit_identical)),
        ("kernel_layer_quantizer", Json::str("uniform4 g64 asym")),
        ("matvec_kernel_ms_scalar_ref", Json::num(t_k_scalar.mean_ms())),
        ("matvec_kernel_ms_blocked", Json::num(t_k_block.mean_ms())),
        ("matvec_kernel_speedup_x", Json::num(kernel_speedup)),
        ("matvec_kernel_tokens_per_sec_scalar_ref", Json::num(1e9 / t_k_scalar.mean_ns)),
        ("matvec_kernel_tokens_per_sec_blocked", Json::num(1e9 / t_k_block.mean_ns)),
        ("decode_bytes", Json::num(decode_bytes)),
        ("adapter_bytes", Json::num(adapter_bytes)),
        ("flops", Json::num(flops)),
        ("achieved_gbps", Json::num(achieved_gbps)),
        ("achieved_gflops", Json::num(achieved_gflops)),
        ("stream_read_ceiling_gbps", Json::num(ceiling_gbps)),
        (
            "roofline_fraction_of_ceiling",
            Json::num(achieved_gbps / ceiling_gbps.max(1e-9)),
        ),
    ]);
    bench::write_json("BENCH_serve.json", &record)?;
    anyhow::ensure!(
        kernel_bit_identical,
        "block decode kernels diverge bit-wise from the scalar reference \
         (recorded in BENCH_serve.json)"
    );
    Ok(tables)
}

/// §Perf evalbatch: the fleet evaluator against the per-outcome
/// `perplexity_native` loop, recorded into `BENCH_evalbatch.json`.
///
/// A sweep grid of w-only + plain-QER rank/scaling variants reuses one
/// cached k=0 quantization per (quantizer, seed) cell, so all those
/// outcomes carry pointer-identical `Arc`-shared packed bases; one extra
/// SRR config quantizes its own base and must stay a singleton. The
/// eval stream is serving-shaped — single-sequence batches over a short
/// context — the regime where the per-outcome loop re-pays the packed
/// base decode (and the per-forward fixed costs) hardest. The bench
/// asserts PPL equivalence (≤ 1e-6 per outcome) between the two paths
/// and records tokens/sec plus the packed-buffer dedup.
pub fn evalbatch_bench(ctx: &mut ExpCtx) -> Result<Vec<Table>> {
    let model = "tiny";
    let fx = ctx.lm(model)?;
    let quant = QuantizerSpec::Mxint { bits: 2, block: 32 };

    // w-only + QER ranks × scalings: all 13 reuse the cached k=0
    // quantization, so they form one shared-base lock-step group …
    let mut configs = vec![SweepConfig::new(quant, Method::WOnly, 0, ScalingKind::Identity)];
    for kind in [ScalingKind::DiagRms, ScalingKind::DiagAbsMean, ScalingKind::Exact] {
        for rank in [2usize, 4, 8, 16] {
            configs.push(SweepConfig::new(quant, Method::Qer, rank, kind));
        }
    }
    // … plus one SRR outcome with its own quantized base (a singleton
    // group, exercising the mixed-grid path)
    configs.push(SweepConfig::new(quant, Method::QerSrr, 8, ScalingKind::DiagRms));

    let metrics = Metrics::new();
    let outs = run_sweep_factored(&fx.params, &fx.cfg, &fx.calib, &configs, &metrics);
    let models: Vec<&FactoredModel> = outs.iter().map(|o| &o.model).collect();
    let fp = fleet_footprint(&models);
    anyhow::ensure!(
        fp.groups == 2,
        "expected one shared-base group + one SRR singleton, got {} groups",
        fp.groups
    );

    // serving-shaped scoring stream: b=1 sequences, short context
    let (b_ev, t_ev) = (1usize, 12usize.min(fx.cfg.seq_len));
    let n_batches = if ctx.quick { 4 } else { 8 };
    let batches: Vec<Vec<i32>> =
        (0..n_batches).map(|i| fx.corpus.train_batch(b_ev, t_ev, 70_000 + i)).collect();
    let mask = vec![1.0f32; b_ev * t_ev];

    // correctness gate before timing: fleet PPL ≡ per-outcome PPL
    let solo: Vec<f64> = models
        .iter()
        .map(|m| perplexity_native_masked(*m, &fx.cfg, &batches, &mask, b_ev, t_ev))
        .collect();
    let fleet = fleet_perplexity(&models, &fx.cfg, &batches, b_ev, t_ev)?;
    for (i, (a, bppl)) in solo.iter().zip(&fleet).enumerate() {
        anyhow::ensure!(
            (a - bppl).abs() <= 1e-6,
            "{}: fleet ppl {bppl} vs per-outcome {a}",
            configs[i].label
        );
    }

    let iters = if ctx.quick { 2 } else { 5 };
    let t_solo = time_fn("per-outcome ppl loop", 1, iters, || {
        models
            .iter()
            .map(|m| perplexity_native_masked(*m, &fx.cfg, &batches, &mask, b_ev, t_ev))
            .collect::<Vec<f64>>()
    });
    let t_fleet = time_fn("fleet ppl", 1, iters, || {
        fleet_perplexity(&models, &fx.cfg, &batches, b_ev, t_ev).expect("gated above")
    });

    let scored_toks = (models.len() * batches.len() * b_ev * (t_ev - 1)) as f64;
    let tps_solo = scored_toks / (t_solo.mean_ns / 1e9);
    let tps_fleet = scored_toks / (t_fleet.mean_ns / 1e9);
    let speedup = t_solo.mean_ns / t_fleet.mean_ns;

    let mut t = Table::new(
        &format!(
            "§Perf evalbatch — fleet vs per-outcome PPL ({} outcomes, {} groups, b={b_ev} \
             t={t_ev}, recorded in BENCH_evalbatch.json)",
            models.len(),
            fp.groups
        ),
        &["path", "mean ms", "tokens/s", "speedup"],
    );
    t.row(vec![
        "per-outcome perplexity_native loop".into(),
        f(t_solo.mean_ms(), 2),
        f(tps_solo, 0),
        "x1.00 (ref)".into(),
    ]);
    t.row(vec![
        "fleet (lock-step groups)".into(),
        f(t_fleet.mean_ms(), 2),
        f(tps_fleet, 0),
        format!("x{speedup:.2}"),
    ]);
    t.row(vec![
        "packed bases resident".into(),
        format!("{} bytes", fp.unique_base_bytes),
        format!("{} unshared", fp.total_base_bytes),
        format!(
            "x{:.2} dedup",
            fp.total_base_bytes as f64 / fp.unique_base_bytes.max(1) as f64
        ),
    ]);

    let record = Json::obj(vec![
        ("model", Json::str(model)),
        ("quick", Json::Bool(ctx.quick)),
        ("grid", Json::arr(configs.iter().map(|c| Json::str(c.label.clone())).collect())),
        ("outcomes", Json::num(models.len() as f64)),
        ("groups", Json::num(fp.groups as f64)),
        ("eval_b", Json::num(b_ev as f64)),
        ("eval_t", Json::num(t_ev as f64)),
        ("eval_batches", Json::num(batches.len() as f64)),
        ("scored_tokens", Json::num(scored_toks)),
        ("per_outcome_ms", Json::num(t_solo.mean_ms())),
        ("fleet_ms", Json::num(t_fleet.mean_ms())),
        ("per_outcome_tokens_per_sec", Json::num(tps_solo)),
        ("fleet_tokens_per_sec", Json::num(tps_fleet)),
        ("fleet_speedup_x", Json::num(speedup)),
        ("ppl_equivalent_1e6", Json::Bool(true)),
        (
            "ppl_max_abs_diff",
            Json::num(
                solo.iter()
                    .zip(&fleet)
                    .map(|(a, bppl)| (a - bppl).abs())
                    .fold(0.0f64, f64::max),
            ),
        ),
        ("peak_packed_bytes_shared", Json::num(fp.unique_base_bytes as f64)),
        ("peak_packed_bytes_per_outcome", Json::num(fp.total_base_bytes as f64)),
        (
            "packed_dedup_x",
            Json::num(fp.total_base_bytes as f64 / fp.unique_base_bytes.max(1) as f64),
        ),
    ]);
    bench::write_json("BENCH_evalbatch.json", &record)?;
    Ok(vec![t])
}

/// Bit-level outcome comparison for the shard bench's equivalence gate.
fn outcomes_identical(a: &[FactoredOutcome], b: &[FactoredOutcome]) -> bool {
    a.len() == b.len()
        && a.iter().zip(b).all(|(oa, ob)| {
            oa.model.ops.len() == ob.model.ops.len()
                && oa
                    .model
                    .ops
                    .iter()
                    .zip(&ob.model.ops)
                    .all(|((na, opa), (nb, opb))| {
                        na == nb
                            && opa.rank() == opb.rank()
                            && opa.densify() == opb.densify()
                    })
                && oa
                    .reports
                    .iter()
                    .zip(&ob.reports)
                    .all(|(ra, rb)| {
                        ra.k_star == rb.k_star
                            && ra.weight_err.to_bits() == rb.weight_err.to_bits()
                            && ra.scaled_err.to_bits() == rb.scaled_err.to_bits()
                    })
        })
}

/// §Perf shard: the multi-process shard plane (`coordinator::shard`),
/// recorded into `BENCH_shard.json`.
///
/// Three gates and two scaling measurements:
/// 1. **equivalence** (hard failure + recorded flags) — sweep outcomes
///    and fleet PPLs through N ∈ {1, 2} single-threaded worker
///    processes are bit-identical to the in-process
///    `SweepRunner::run_factored` + `fleet_perplexity`;
/// 2. **scaling** — wall-clock of the sharded pipeline (phase-B2 jobs +
///    fleet jobs over the wire) at N=2 vs N=1: the speedup is the shard
///    plane's scaling efficiency on a 2-core runner, the number a
///    multi-host deployment inherits;
/// 3. **TCP loopback** — the same N=2 run with workers dialing in over
///    `127.0.0.1` (`ShardSession::spawn_tcp`) instead of pipes:
///    `tcp_bit_identical` gates equivalence through the TCP transport
///    and `tcp_vs_pipe_n2` records the loopback framing overhead — the
///    per-byte cost a real remote deployment starts from before network
///    latency;
/// 4. **wedge recovery** — N=2 in-memory workers, one of which goes
///    silent after its first byte (stream open, no frames, no
///    heartbeats — only the heartbeat deadline can clear it):
///    `wedge_recovered` gates that the host declares the wedge,
///    requeues onto the survivor, and still finishes bit-identically,
///    and `wedge_recovery_secs` records the end-to-end cost of riding
///    out a wedged worker at a `wedge_timeout_secs` deadline.
pub fn shard_bench(ctx: &mut ExpCtx) -> Result<Vec<Table>> {
    let model = "tiny";
    let fx = ctx.lm(model)?;
    let quant = QuantizerSpec::Mxint { bits: 2, block: 32 };

    // one shared-base cell (w-only + QER ranks — lock-step group across
    // the wire) plus an SRR block whose per-job preserve/quantize/SVD
    // work dominates, so the grid is B2-heavy and scaling is visible
    let mut configs = vec![SweepConfig::new(quant, Method::WOnly, 0, ScalingKind::Identity)];
    for rank in [4usize, 8] {
        configs.push(SweepConfig::new(quant, Method::Qer, rank, ScalingKind::DiagRms));
    }
    let srr_ranks: &[usize] = if ctx.quick { &[4, 8, 16] } else { &[2, 4, 8, 12, 16, 24] };
    for &rank in srr_ranks {
        configs.push(SweepConfig::new(quant, Method::QerSrr, rank, ScalingKind::DiagRms));
        configs.push(SweepConfig::new(quant, Method::FixedSplitHalf, rank, ScalingKind::DiagRms));
    }

    // serving-shaped eval stream for the fleet half
    let (b_ev, t_ev) = (1usize, 12usize.min(fx.cfg.seq_len));
    let n_batches = if ctx.quick { 4 } else { 8 };
    let batches: Vec<Vec<i32>> =
        (0..n_batches).map(|i| fx.corpus.train_batch(b_ev, t_ev, 90_000 + i)).collect();

    // in-process reference (full host parallelism)
    let metrics = Metrics::new();
    let t0 = Instant::now();
    let expect = SweepRunner::new(&fx.params, &fx.cfg, &fx.calib, &metrics)
        .run_factored(&configs);
    let exp_models: Vec<&FactoredModel> = expect.iter().map(|o| &o.model).collect();
    let exp_ppl = fleet_perplexity(&exp_models, &fx.cfg, &batches, b_ev, t_ev)?;
    let inproc_secs = t0.elapsed().as_secs_f64();

    // sharded runs: N single-threaded workers each
    let mut shard_secs = Vec::new();
    let mut equiv_flags = Vec::new();
    for n in [1usize, 2] {
        let mut session = ShardSession::spawn(&ShardOptions::with_workers(n))?;
        let runner = ShardedSweepRunner::new(&fx.params, &fx.cfg, &fx.calib, &metrics);
        let t0 = Instant::now();
        let outs = runner.run_factored(&mut session, &configs)?;
        let models: Vec<&FactoredModel> = outs.iter().map(|o| &o.model).collect();
        let ppl = fleet_perplexity_sharded(
            &mut session,
            &models,
            &fx.cfg,
            &batches,
            b_ev,
            t_ev,
            &metrics,
        )?;
        let secs = t0.elapsed().as_secs_f64();
        session.shutdown();

        let outcomes_ok = outcomes_identical(&expect, &outs);
        let ppl_ok = exp_ppl.iter().zip(&ppl).all(|(a, b)| a.to_bits() == b.to_bits());
        anyhow::ensure!(outcomes_ok, "N={n}: sharded sweep outcomes diverge from in-process");
        anyhow::ensure!(ppl_ok, "N={n}: sharded fleet PPLs diverge from in-process");
        shard_secs.push(secs);
        equiv_flags.push((n, outcomes_ok, ppl_ok));
    }
    let speedup = shard_secs[0] / shard_secs[1].max(1e-9);

    // run_jobs overwrites the shard.* metrics per session, so snapshot
    // the pipe legs' counters before the TCP leg clobbers them
    let pipe_tx_bytes = metrics.get("shard.tx_bytes");
    let pipe_rx_bytes = metrics.get("shard.rx_bytes");
    let pipe_requeued = metrics.get("shard.requeued");

    // TCP loopback leg: N=2 single-threaded workers dialing back over
    // 127.0.0.1 — same dispatcher and jobs, only the transport differs.
    // Equivalence is recorded (then asserted *after* the record is
    // written, so a divergence still lands in BENCH_shard.json for the
    // CI gate to flag).
    let (tcp_secs, tcp_ok) = {
        let mut session = ShardSession::spawn_tcp(&ShardOptions::with_workers(2))?;
        let runner = ShardedSweepRunner::new(&fx.params, &fx.cfg, &fx.calib, &metrics);
        let t0 = Instant::now();
        let outs = runner.run_factored(&mut session, &configs)?;
        let models: Vec<&FactoredModel> = outs.iter().map(|o| &o.model).collect();
        let ppl = fleet_perplexity_sharded(
            &mut session,
            &models,
            &fx.cfg,
            &batches,
            b_ev,
            t_ev,
            &metrics,
        )?;
        let secs = t0.elapsed().as_secs_f64();
        session.shutdown();
        let ok = outcomes_identical(&expect, &outs)
            && exp_ppl.iter().zip(&ppl).all(|(a, b)| a.to_bits() == b.to_bits());
        (secs, ok)
    };

    // Wedge-recovery leg: one healthy worker plus one that stalls
    // silently after its first byte. Fresh Metrics so the counters are
    // unambiguously this leg's. The deadline is generous against the
    // 100ms worker heartbeat cadence (20×), so a slow CI runner can't
    // false-positive a healthy worker into a wedge.
    let wedge_timeout = std::time::Duration::from_millis(2000);
    let (wedge_secs, wedge_identical, wedge_count, wedge_requeued) = {
        use crate::coordinator::jobs::byte_pipe;
        use crate::coordinator::shard::run_worker_paced;
        use crate::coordinator::{FaultPlan, FaultTransport, Transport};
        let mk_worker = |plan: FaultPlan| -> Box<dyn Transport> {
            let (host_to_worker, worker_input) = byte_pipe(1 << 16);
            let (worker_output, worker_to_host) = byte_pipe(1 << 16);
            std::thread::spawn(move || {
                // a severed pipe here is the simulated crash — host's problem
                let _ = run_worker_paced(
                    worker_input,
                    worker_output,
                    None,
                    std::time::Duration::from_millis(100),
                );
            });
            Box::new(FaultTransport::new(host_to_worker, worker_to_host, plan))
        };
        let wmetrics = Metrics::new();
        let transports = vec![
            mk_worker(FaultPlan::default()),
            mk_worker(FaultPlan { stall_rx_after: Some(1), ..Default::default() }),
        ];
        let mut session = ShardSession::from_transports(transports)?;
        session.set_heartbeat_timeout(wedge_timeout);
        let runner = ShardedSweepRunner::new(&fx.params, &fx.cfg, &fx.calib, &wmetrics);
        let t0 = Instant::now();
        let outs = runner.run_factored(&mut session, &configs)?;
        let secs = t0.elapsed().as_secs_f64();
        session.shutdown();
        (
            secs,
            outcomes_identical(&expect, &outs),
            wmetrics.get("shard.wedged"),
            wmetrics.get("shard.requeued"),
        )
    };
    let wedge_recovered = wedge_identical && wedge_count >= 1.0;

    let record = Json::obj(vec![
        ("model", Json::str(model)),
        ("quick", Json::Bool(ctx.quick)),
        ("grid", Json::arr(configs.iter().map(|c| Json::str(c.label.clone())).collect())),
        ("sweep_jobs", Json::num((configs.len() * expect[0].model.ops.len()) as f64)),
        ("eval_batches", Json::num(batches.len() as f64)),
        ("worker_threads", Json::num(1.0)),
        ("inprocess_secs", Json::num(inproc_secs)),
        ("shard_n1_secs", Json::num(shard_secs[0])),
        ("shard_n2_secs", Json::num(shard_secs[1])),
        ("speedup_n2_over_n1", Json::num(speedup)),
        ("scaling_efficiency_n2", Json::num(speedup / 2.0)),
        (
            "outcomes_identical_n1",
            Json::Bool(equiv_flags[0].1),
        ),
        ("fleet_ppl_identical_n1", Json::Bool(equiv_flags[0].2)),
        ("outcomes_identical_n2", Json::Bool(equiv_flags[1].1)),
        ("fleet_ppl_identical_n2", Json::Bool(equiv_flags[1].2)),
        ("tcp_n2_secs", Json::num(tcp_secs)),
        ("tcp_vs_pipe_n2", Json::num(shard_secs[1] / tcp_secs.max(1e-9))),
        ("tcp_bit_identical", Json::Bool(tcp_ok)),
        ("tcp_tx_bytes", Json::num(metrics.get("shard.tx_bytes"))),
        ("tcp_rx_bytes", Json::num(metrics.get("shard.rx_bytes"))),
        ("shard_tx_bytes", Json::num(pipe_tx_bytes)),
        ("shard_rx_bytes", Json::num(pipe_rx_bytes)),
        ("shard_requeued", Json::num(pipe_requeued)),
        ("wedge_timeout_secs", Json::num(wedge_timeout.as_secs_f64())),
        ("wedge_recovery_secs", Json::num(wedge_secs)),
        ("wedge_workers_wedged", Json::num(wedge_count)),
        ("wedge_requeued", Json::num(wedge_requeued)),
        ("wedge_recovered", Json::Bool(wedge_recovered)),
    ]);
    bench::write_json("BENCH_shard.json", &record)?;
    anyhow::ensure!(
        tcp_ok,
        "TCP N=2: sharded results diverge from in-process (recorded in BENCH_shard.json)"
    );
    anyhow::ensure!(
        wedge_recovered,
        "wedge leg: stalled worker not recovered bit-identically \
         (wedged={wedge_count}, recorded in BENCH_shard.json)"
    );

    let mut t = Table::new(
        &format!(
            "§Perf shard — multi-process plane, {} sweep configs + {} eval batches, \
             model={model} (recorded in BENCH_shard.json)",
            configs.len(),
            batches.len()
        ),
        &["path", "secs", "vs N=1", "bit-identical"],
    );
    t.row(vec![
        "in-process (reference)".into(),
        f(inproc_secs, 3),
        String::new(),
        "—".into(),
    ]);
    t.row(vec![
        "sharded, N=1 worker (1 thread)".into(),
        f(shard_secs[0], 3),
        "x1.00 (ref)".into(),
        "yes".into(),
    ]);
    t.row(vec![
        "sharded, N=2 workers (1 thread each)".into(),
        f(shard_secs[1], 3),
        format!("x{speedup:.2}"),
        "yes".into(),
    ]);
    t.row(vec![
        "sharded, N=2 TCP loopback workers".into(),
        f(tcp_secs, 3),
        format!("x{:.2}", shard_secs[0] / tcp_secs.max(1e-9)),
        "yes".into(),
    ]);
    t.row(vec![
        "sharded, N=2, one wedged (heartbeat requeue)".into(),
        f(wedge_secs, 3),
        format!("x{:.2}", shard_secs[0] / wedge_secs.max(1e-9)),
        "yes".into(),
    ]);
    Ok(vec![t])
}

/// Self-cleaning spill directory for the bench legs (the guard removes
/// the dir even when a gate below fails and unwinds early).
struct SpillDirGuard(std::path::PathBuf);

impl SpillDirGuard {
    fn new(tag: &str) -> Result<SpillDirGuard> {
        use std::sync::atomic::{AtomicU64, Ordering};
        static NEXT: AtomicU64 = AtomicU64::new(0);
        let dir = std::env::temp_dir().join(format!(
            "srr-spill-bench-{tag}-{}-{}",
            std::process::id(),
            NEXT.fetch_add(1, Ordering::Relaxed)
        ));
        std::fs::create_dir_all(&dir)?;
        Ok(SpillDirGuard(dir))
    }
}

impl Drop for SpillDirGuard {
    fn drop(&mut self) {
        let _ = std::fs::remove_dir_all(&self.0);
    }
}

/// `--exp spill`: the out-of-core sweep store (`coordinator::spill`),
/// recorded into `BENCH_spill.json`.
///
/// Two gates and the working-set measurements:
/// 1. **`spill_bit_identical`** (recorded, then asserted) — the same
///    grid through `run_sweep_spilled` under a deliberately small blob
///    cap is bit-identical to the in-memory `SweepRunner::run_factored`:
///    outcomes, lock-step `Arc` grouping, and fleet PPL;
/// 2. **`resume_bit_identical`** — a second spilled run is killed at a
///    mid-sweep chunk boundary (`SpillOptions::abort_after_records`,
///    fired after the record is durable — the in-process analogue of
///    `kill -9` between fsyncs), reopened, and resumed: completed
///    chunks replay from the manifest, the rest re-runs, and the merged
///    outcome is bit-identical;
/// 3. **working set** — `peak_resident_bytes` (the store's peak-RSS
///    proxy: high-water strong-cache residency) against the grid's
///    fully-resident packed footprint, plus durable spill / reload
///    throughput.
pub fn spill_bench(ctx: &mut ExpCtx) -> Result<Vec<Table>> {
    let model = "tiny";
    let fx = ctx.lm(model)?;
    let quant = QuantizerSpec::Mxint { bits: 2, block: 32 };

    // a lock-step pair (w-only + QER over one quantization) plus an SRR
    // block: the spilled reassembly has to reproduce both the shared
    // and the per-cell Arc topologies
    let mut configs = vec![SweepConfig::new(quant, Method::WOnly, 0, ScalingKind::Identity)];
    for rank in [4usize, 8] {
        configs.push(SweepConfig::new(quant, Method::Qer, rank, ScalingKind::DiagRms));
    }
    let srr_ranks: &[usize] = if ctx.quick { &[4, 8] } else { &[2, 4, 8, 16] };
    for &rank in srr_ranks {
        configs.push(SweepConfig::new(quant, Method::QerSrr, rank, ScalingKind::DiagRms));
    }

    let (b_ev, t_ev) = (1usize, 12usize.min(fx.cfg.seq_len));
    let n_batches = if ctx.quick { 4 } else { 8 };
    let batches: Vec<Vec<i32>> =
        (0..n_batches).map(|i| fx.corpus.train_batch(b_ev, t_ev, 90_000 + i)).collect();

    // in-memory reference (the whole grid resident at once)
    let metrics = Metrics::new();
    let t0 = Instant::now();
    let expect = SweepRunner::new(&fx.params, &fx.cfg, &fx.calib, &metrics)
        .run_factored(&configs);
    let inmem_secs = t0.elapsed().as_secs_f64();
    let exp_models: Vec<&FactoredModel> = expect.iter().map(|o| &o.model).collect();
    let exp_ppl = fleet_perplexity(&exp_models, &fx.cfg, &batches, b_ev, t_ev)?;
    let fp = fleet_footprint(&exp_models);

    // spilled leg: 1 MiB blob cap — far below one layer's artifacts, so
    // every phase streams through eviction and reload
    let cap_bytes = 1usize << 20;
    let dir = SpillDirGuard::new("main")?;
    let store =
        SpillStore::open(&dir.0, SpillOptions { cap_bytes, ..Default::default() })?;
    let t0 = Instant::now();
    let spilled =
        run_sweep_spilled(&fx.params, &fx.cfg, &fx.calib, &configs, &metrics, &store)?;
    let spilled_secs = t0.elapsed().as_secs_f64();
    let stats = store.stats();
    let sp_models: Vec<&FactoredModel> = spilled.iter().map(|o| &o.model).collect();
    let sp_ppl = fleet_perplexity(&sp_models, &fx.cfg, &batches, b_ev, t_ev)?;
    let spill_identical = outcomes_identical(&expect, &spilled)
        && crate::eval::group_by_shared_bases(&exp_models)
            == crate::eval::group_by_shared_bases(&sp_models)
        && exp_ppl.iter().zip(&sp_ppl).all(|(a, b)| a.to_bits() == b.to_bits());
    drop(store);

    // resume leg: kill a fresh spilled run at a mid-sweep chunk
    // boundary, reopen the dir, run to completion, compare
    let total_records = stats.records;
    let kill_at = total_records / 2 + 1;
    let dir2 = SpillDirGuard::new("resume")?;
    let store = SpillStore::open(
        &dir2.0,
        SpillOptions { cap_bytes, abort_after_records: Some(kill_at), ..Default::default() },
    )?;
    let killed =
        run_sweep_spilled(&fx.params, &fx.cfg, &fx.calib, &configs, &metrics, &store);
    anyhow::ensure!(killed.is_err(), "the injected kill at record {kill_at} must abort");
    drop(store);
    let store = SpillStore::open(&dir2.0, SpillOptions { cap_bytes, ..Default::default() })?;
    let records_survived = store.stats().records;
    let t0 = Instant::now();
    let resumed =
        run_sweep_spilled(&fx.params, &fx.cfg, &fx.calib, &configs, &metrics, &store)?;
    let resume_secs = t0.elapsed().as_secs_f64();
    let rs_models: Vec<&FactoredModel> = resumed.iter().map(|o| &o.model).collect();
    let rs_ppl = fleet_perplexity(&rs_models, &fx.cfg, &batches, b_ev, t_ev)?;
    let resume_identical = outcomes_identical(&expect, &resumed)
        && crate::eval::group_by_shared_bases(&exp_models)
            == crate::eval::group_by_shared_bases(&rs_models)
        && exp_ppl.iter().zip(&rs_ppl).all(|(a, b)| a.to_bits() == b.to_bits());
    drop(store);

    let record = Json::obj(vec![
        ("model", Json::str(model)),
        ("quick", Json::Bool(ctx.quick)),
        ("grid", Json::arr(configs.iter().map(|c| Json::str(c.label.clone())).collect())),
        ("cap_bytes", Json::num(cap_bytes as f64)),
        ("inmem_secs", Json::num(inmem_secs)),
        ("spilled_secs", Json::num(spilled_secs)),
        ("spill_overhead_x", Json::num(spilled_secs / inmem_secs.max(1e-9))),
        ("bytes_spilled", Json::num(stats.bytes_spilled as f64)),
        ("bytes_reloaded", Json::num(stats.bytes_reloaded as f64)),
        (
            "spill_mb_per_s",
            Json::num(stats.bytes_spilled as f64 / 1e6 / spilled_secs.max(1e-9)),
        ),
        (
            "reload_mb_per_s",
            Json::num(stats.bytes_reloaded as f64 / 1e6 / spilled_secs.max(1e-9)),
        ),
        ("peak_resident_bytes", Json::num(stats.peak_resident_bytes as f64)),
        ("resident_base_bytes_if_in_memory", Json::num(fp.unique_base_bytes as f64)),
        ("manifest_records", Json::num(total_records as f64)),
        ("kill_at_record", Json::num(kill_at as f64)),
        ("records_survived_kill", Json::num(records_survived as f64)),
        ("resume_secs", Json::num(resume_secs)),
        ("spill_bit_identical", Json::Bool(spill_identical)),
        ("resume_bit_identical", Json::Bool(resume_identical)),
    ]);
    bench::write_json("BENCH_spill.json", &record)?;
    anyhow::ensure!(
        spill_identical,
        "spilled sweep diverges from in-memory (recorded in BENCH_spill.json)"
    );
    anyhow::ensure!(
        resume_identical,
        "killed-and-resumed sweep diverges from in-memory \
         (killed at record {kill_at}, recorded in BENCH_spill.json)"
    );

    let mut t = Table::new(
        &format!(
            "§Perf spill — out-of-core sweep store, {} configs, cap {} KiB, \
             model={model} (recorded in BENCH_spill.json)",
            configs.len(),
            cap_bytes >> 10
        ),
        &["path", "secs", "working set", "bit-identical"],
    );
    t.row(vec![
        "in-memory (reference)".into(),
        f(inmem_secs, 3),
        format!("{} KiB", fp.unique_base_bytes >> 10),
        "—".into(),
    ]);
    t.row(vec![
        "spilled (1 MiB cap)".into(),
        f(spilled_secs, 3),
        format!("{} KiB peak", stats.peak_resident_bytes >> 10),
        "yes".into(),
    ]);
    t.row(vec![
        format!("killed at record {kill_at}/{total_records}, resumed"),
        f(resume_secs, 3),
        String::new(),
        "yes".into(),
    ]);
    Ok(vec![t])
}

/// `--exp serve_live`: the continuous-batching daemon under live TCP
/// load, gated on end-to-end bit-identity (writes
/// `BENCH_serve_live.json`).
///
/// Quantizes the tiny model into rank variants sharing one packed base
/// per linear, serves them behind one loopback daemon, drives ≥ 8
/// concurrent open-loop clients against it, then replays **every
/// completed request** through the serial one-at-a-time oracle
/// ([`FleetEngine::run_to_completion`]) and asserts bit-identical
/// outputs (token-exact generates, f64-bit-exact scores). The record is
/// written before the assertions so a divergence still lands in the
/// JSON for the CI gate.
pub fn serve_live_bench(ctx: &mut ExpCtx) -> Result<Vec<Table>> {
    use crate::serve::daemon::{
        run_open_loop, Daemon, DaemonConfig, FleetEngine, LoadSpec, ReqKind, ServeReply, StepOut,
    };

    let model = "tiny";
    let fx = ctx.lm(model)?;

    // one quantizer/seed across ranks → shared Arc<PackedMat> bases
    let quant = QuantizerSpec::Mxint { bits: 2, block: 32 };
    let ranks = [4usize, 8];
    let configs: Vec<SweepConfig> = ranks
        .iter()
        .map(|&r| {
            SweepConfig::new(quant, Method::Qer, r, ScalingKind::DiagRms).labeled(&format!("r{r}"))
        })
        .collect();
    let metrics = Metrics::new();
    let outs =
        SweepRunner::new(&fx.params, &fx.cfg, &fx.calib, &metrics).run_factored(&configs);
    let as_refs: Vec<&FactoredModel> = outs.iter().map(|o| &o.model).collect();
    let variants_share_base = crate::eval::group_by_shared_bases(&as_refs).len() == 1;

    let mk_variants = || -> Vec<(String, FactoredModel)> {
        configs.iter().zip(&outs).map(|(c, o)| (c.label.clone(), o.model.clone())).collect()
    };
    // two engines off the same outcomes: one moves into the daemon, the
    // other replays requests serially as the oracle (FactoredModel
    // clones share their packed buffers via Arc, so this is cheap)
    let engine = FleetEngine::new(fx.cfg.clone(), mk_variants())?;
    let oracle = FleetEngine::new(fx.cfg.clone(), mk_variants())?;

    let mut daemon = Daemon::new(
        engine,
        DaemonConfig { max_slots: 64, max_batch: 8, ..Default::default() },
    );
    let addr = daemon.bind("127.0.0.1:0")?;
    let handle = daemon.spawn();

    let spec = LoadSpec {
        clients: 8,
        per_client: if ctx.quick { 8 } else { 24 },
        gap: std::time::Duration::from_millis(3),
        prompt_len: 6,
        max_new: 4,
        vocab: fx.cfg.vocab,
        variants: configs.iter().map(|c| c.label.clone()).collect(),
        score_every: 3,
        seed: 0xC0FFEE,
    };
    let t0 = Instant::now();
    let report = run_open_loop(&addr.to_string(), &spec)?;
    let load_secs = t0.elapsed().as_secs_f64();
    handle.join();

    // serial-oracle replay of every completed request
    let mut checked = 0usize;
    let mut identical = true;
    for o in &report.outcomes {
        let vi = oracle
            .variant_index(&o.variant)
            .ok_or_else(|| anyhow::anyhow!("unknown variant {:?} in outcome", o.variant))?;
        let ok = match &o.reply {
            ServeReply::Tokens { tokens, .. } => {
                checked += 1;
                matches!(
                    oracle.run_to_completion(vi, &o.tokens, o.kind)?,
                    StepOut::Tokens(serial) if &serial == tokens
                )
            }
            ServeReply::Score { nll, count, .. } => {
                checked += 1;
                matches!(
                    oracle.run_to_completion(vi, &o.tokens, ReqKind::Score)?,
                    StepOut::Score { nll: s_nll, count: s_count }
                        if s_nll.to_bits() == nll.to_bits() && s_count == *count
                )
            }
            ServeReply::Busy { .. } | ServeReply::Error { .. } => true,
        };
        identical &= ok;
    }
    let batched_bit_identical = identical && checked > 0;

    let record = Json::obj(vec![
        ("model", Json::str(model)),
        ("quick", Json::Bool(ctx.quick)),
        ("variants", Json::arr(configs.iter().map(|c| Json::str(c.label.clone())).collect())),
        ("variants_share_base", Json::Bool(variants_share_base)),
        ("clients", Json::num(spec.clients as f64)),
        ("requests", Json::num(report.sent as f64)),
        ("completed", Json::num(report.completed as f64)),
        ("busy", Json::num(report.busy as f64)),
        ("errors", Json::num(report.errors as f64)),
        ("oracle_checked", Json::num(checked as f64)),
        ("load_secs", Json::num(load_secs)),
        ("sustained_rps", Json::num(report.sustained_rps)),
        ("p50_latency_ms", Json::num(report.p50_ms)),
        ("p99_latency_ms", Json::num(report.p99_ms)),
        ("batched_bit_identical", Json::Bool(batched_bit_identical)),
    ]);
    bench::write_json("BENCH_serve_live.json", &record)?;
    anyhow::ensure!(
        variants_share_base,
        "rank variants do not share packed bases (recorded in BENCH_serve_live.json)"
    );
    anyhow::ensure!(
        batched_bit_identical,
        "batched daemon outputs diverge from the serial oracle over {checked} \
         completed requests (recorded in BENCH_serve_live.json)"
    );
    anyhow::ensure!(
        report.completed > 0 && report.p99_ms.is_finite(),
        "load run completed no requests (recorded in BENCH_serve_live.json)"
    );

    let mut t = Table::new(
        &format!(
            "§Perf serve_live — continuous-batching daemon, {} clients × {} requests, \
             variants [{}] off one shared base, model={model} \
             (recorded in BENCH_serve_live.json)",
            spec.clients,
            spec.per_client,
            configs.iter().map(|c| c.label.clone()).collect::<Vec<_>>().join(", ")
        ),
        &["metric", "value"],
    );
    t.row(vec!["completed / sent".into(), format!("{} / {}", report.completed, report.sent)]);
    t.row(vec!["busy (shed)".into(), format!("{}", report.busy)]);
    t.row(vec!["sustained req/s".into(), f(report.sustained_rps, 1)]);
    t.row(vec!["p50 latency (ms)".into(), f(report.p50_ms, 2)]);
    t.row(vec!["p99 latency (ms)".into(), f(report.p99_ms, 2)]);
    t.row(vec![
        "batched ≡ serial oracle".into(),
        format!("{batched_bit_identical} ({checked} replayed)"),
    ]);
    Ok(vec![t])
}

/// §Perf suite: the per-layer hot paths.
pub fn perf_suite(ctx: &mut ExpCtx) -> Result<Vec<Table>> {
    let mut tables = vec![];
    let iters = if ctx.quick { 3 } else { 10 };

    // --- L1: kernel artifacts through PJRT vs rust-native ---------------
    {
        let mut rng = Rng::new(1);
        let w = Mat::randn(128, 256, 1.0, &mut rng);
        let q3 = MxintQuantizer::new(3, 32);
        let t_native = time_fn("mxint_rust", 2, iters, || {
            q3.quantize(&w, &Default::default())
        });
        let inputs = [TensorValue::from_mat(&w)];
        ctx.engine.run("kernel_mxint3", &inputs)?; // warm compile cache
        let t_kernel = time_fn("mxint_pallas", 2, iters, || {
            ctx.engine.run("kernel_mxint3", &inputs).unwrap()
        });

        let x = Mat::randn(64, 256, 1.0, &mut rng);
        let l = Mat::randn(256, 64, 0.1, &mut rng);
        let r = Mat::randn(64, 256, 0.1, &mut rng);
        let qm = Mat::randn(256, 256, 0.1, &mut rng);
        let qlr_in = [
            TensorValue::from_mat(&x),
            TensorValue::from_mat(&qm),
            TensorValue::from_mat(&l),
            TensorValue::from_mat(&r),
        ];
        ctx.engine.run("kernel_qlr", &qlr_in)?;
        let t_qlr = time_fn("qlr_fused", 2, iters, || {
            ctx.engine.run("kernel_qlr", &qlr_in).unwrap()
        });
        let t_qlr_mat = time_fn("qlr_materialized", 2, iters, || {
            // materialize W_hat then one dense GEMM — the unfused baseline
            let what = qm.add(&matmul(&l, &r));
            matmul(&x, &what)
        });

        let mut t = Table::new(
            "§Perf L1 — kernel hot paths (128x256 mxint3; 64x256x256 r64 qlr)",
            &["path", "mean ms", "p95 ms"],
        );
        for tm in [&t_native, &t_kernel, &t_qlr, &t_qlr_mat] {
            t.row(vec![tm.name.clone(), f(tm.mean_ms(), 3), f(tm.p95_ns / 1e6, 3)]);
        }
        tables.push(t);
    }

    // --- L2/engine: model forward throughput ----------------------------
    {
        let fx = ctx.lm("tiny")?;
        let b = ctx.engine.manifest().lm_batch;
        let t_len = fx.cfg.seq_len;
        let mut inputs = fx.params.flat()?;
        let mut rng = Rng::new(3);
        let toks: Vec<i32> = (0..b * t_len).map(|_| rng.below(fx.cfg.vocab) as i32).collect();
        inputs.push(TensorValue::i32(vec![b, t_len], toks));
        ctx.engine.run("lm_fwd_tiny", &inputs)?;
        let tm = time_fn("lm_fwd_tiny", 2, iters, || {
            ctx.engine.run("lm_fwd_tiny", &inputs).unwrap()
        });
        let toks_per_s = (b * t_len) as f64 / (tm.mean_ns / 1e9);
        let mut t = Table::new(
            "§Perf engine — AOT forward throughput",
            &["artifact", "mean ms", "tokens/s"],
        );
        t.row(vec!["lm_fwd_tiny".into(), f(tm.mean_ms(), 2), f(toks_per_s, 0)]);
        tables.push(t);
    }

    // --- L3: linalg primitives at production sizes -----------------------
    {
        let mut rng = Rng::new(5);
        let n = if ctx.quick { 128 } else { 512 };
        let b = Mat::randn(n, n + 8, 1.0, &mut rng);
        let g = matmul_nt(&b, &b).scale(1.0 / (n + 8) as f32);
        let t_eigh = time_fn(&format!("eigh_{n}"), 0, 3.min(iters), || eigh(&g));
        let a = Mat::randn(n, n, 1.0, &mut rng);
        let t_rsvd = time_fn(&format!("rsvd_r8_{n}"), 0, 3.min(iters), || {
            let mut r2 = Rng::new(9);
            randomized_svd(&a, 8, 4, &mut r2)
        });
        let small = Mat::randn(96, 96, 1.0, &mut rng);
        let t_jac = time_fn("jacobi_svd_96", 0, 3.min(iters), || jacobi_svd(&small));
        let t_mm = time_fn(&format!("matmul_{n}"), 1, iters, || matmul(&a, &a));
        let flops = 2.0 * (n as f64).powi(3);
        let mut t = Table::new(
            "§Perf L3 — linalg primitives",
            &["op", "mean ms", "note"],
        );
        t.row(vec![t_eigh.name.clone(), f(t_eigh.mean_ms(), 1), "tred2+tqli".into()]);
        t.row(vec![t_rsvd.name.clone(), f(t_rsvd.mean_ms(), 1), "n_iter=4, oversample 2r".into()]);
        t.row(vec![t_jac.name.clone(), f(t_jac.mean_ms(), 1), "one-sided".into()]);
        t.row(vec![
            t_mm.name.clone(),
            f(t_mm.mean_ms(), 1),
            format!("{:.2} GFLOP/s", flops / (t_mm.mean_ns / 1e9) / 1e9),
        ]);
        tables.push(t);
    }

    // --- serving path: fused QLR LM forward vs materialized --------------
    if false {  // requires the small fixture; see EXPERIMENTS.md budget note
        let fx = ctx.lm("small")?;
        let b = ctx.engine.manifest().lm_batch;
        let t_len = fx.cfg.seq_len;
        // build QLR inputs: dense params reshaped as q + zero adapters
        let mut inputs = vec![];
        for name in crate::model::Params::param_order(&fx.cfg) {
            if name == "head" {
                continue;
            }
            let v = fx.params.get(&name)?.clone();
            if crate::model::Params::param_shape(&name, &fx.cfg, fx.cfg.vocab).len() == 2
                && name.contains('.')
                && !name.ends_with("ln1")
                && !name.ends_with("ln2")
            {
                let m = v.to_mat();
                inputs.push(v);
                inputs.push(TensorValue::f32(vec![m.rows, 64], vec![0.0; m.rows * 64]));
                inputs.push(TensorValue::f32(vec![64, m.cols], vec![0.0; 64 * m.cols]));
            } else {
                inputs.push(v);
            }
        }
        inputs.push(fx.params.get("head")?.clone());
        let mut rng = Rng::new(11);
        let toks: Vec<i32> = (0..b * t_len).map(|_| rng.below(fx.cfg.vocab) as i32).collect();
        inputs.push(TensorValue::i32(vec![b, t_len], toks.clone()));
        ctx.engine.run("qlr_lm_fwd_small_r64", &inputs)?;
        let t_fused = time_fn("qlr_lm_fwd_small_r64", 1, iters.min(5), || {
            ctx.engine.run("qlr_lm_fwd_small_r64", &inputs).unwrap()
        });
        let mut dense_inputs = fx.params.flat()?;
        dense_inputs.push(TensorValue::i32(vec![b, t_len], toks));
        ctx.engine.run("lm_fwd_small", &dense_inputs)?;
        let t_dense = time_fn("lm_fwd_small(dense)", 1, iters.min(5), || {
            ctx.engine.run("lm_fwd_small", &dense_inputs).unwrap()
        });
        let mut t = Table::new(
            "§Perf serving — fused Pallas QLR forward vs dense materialized",
            &["path", "mean ms", "relative"],
        );
        t.row(vec![t_dense.name.clone(), f(t_dense.mean_ms(), 2), "x1.00".into()]);
        t.row(vec![
            t_fused.name.clone(),
            f(t_fused.mean_ms(), 2),
            format!("x{:.2}", t_fused.mean_ns / t_dense.mean_ns),
        ]);
        tables.push(t);
    }

    Ok(tables)
}

/// `--exp budget`: the model-wide rank/bit budget allocator against the
/// best *uniform* `(bits, rank)` baseline at equal bytes, recorded into
/// `BENCH_budget.json` and CI-gated.
///
/// Three budget points are pinned one byte *below* successive uniform
/// byte levels, so every uniform baseline is forced down a level and
/// strands slack the allocator can spend on the most error-sensitive
/// layers. All six plans (allocated + uniform at each point) execute
/// through one heterogeneous sweep grid — shared phase-A prep — and are
/// scored with the native serving-path perplexity, which is fully
/// deterministic here, so `allocated_beats_uniform` is a hard gate, not
/// a statistical one. `allocation_bit_identical` gates the other seam:
/// planning over an N=2 sharded probe prep must yield byte-for-byte the
/// same [`crate::coordinator::BudgetPlan`] as in-process planning.
pub fn budget_bench(ctx: &mut ExpCtx) -> Result<Vec<Table>> {
    let model = "tiny";
    let fx = ctx.lm(model)?;
    let metrics = Metrics::new();
    let runner = SweepRunner::new(&fx.params, &fx.cfg, &fx.calib, &metrics);

    let mut spec = BudgetSpec::new(0);
    spec.bits_choices = vec![2, 3, 4];
    spec.rank_choices = if ctx.quick { vec![0, 4, 8] } else { vec![0, 4, 8, 16] };
    spec.seed = 1;

    let t0 = Instant::now();
    let profiles = runner.budget_profiles(&spec)?;
    let profile_secs = t0.elapsed().as_secs_f64();

    // uniform byte level for candidate cell (bits index, rank index)
    let level =
        |bi: usize, ri: usize| -> u64 { profiles.iter().map(|p| p.bytes(&spec, bi, ri)).sum() };
    // one byte under each level: (3b, r4), (3b, r8), (4b, r8) — all
    // present in both quick and full rank grids
    let points: Vec<u64> = vec![level(1, 1) - 1, level(1, 2) - 1, level(2, 2) - 1];

    let mut specs = Vec::new();
    let mut plans = Vec::new(); // (allocated, uniform) per point
    let mut configs = Vec::new();
    for (i, &budget) in points.iter().enumerate() {
        let mut sp = spec.clone();
        sp.budget_bytes = budget;
        let alloc = allocate(&profiles, &sp)?;
        let uni = uniform_plan(&profiles, &sp)?;
        configs.push(alloc.sweep_config().labeled(&format!("budget/alloc{i}")));
        configs.push(uni.sweep_config().labeled(&format!("budget/uniform{i}")));
        specs.push(sp);
        plans.push((alloc, uni));
    }

    // one grid run executes all six plans against shared phase-A work
    let t0 = Instant::now();
    let outs = runner.run_factored(&configs);
    let run_secs = t0.elapsed().as_secs_f64();

    let b = ctx.engine.manifest().lm_batch;
    let t_len = fx.cfg.seq_len;
    let batches = ctx.ppl_batches(model)?;
    let bf16_ppl = perplexity_native(&fx.params, &fx.cfg, &batches, b, t_len);

    // the sharded seam: same probe prep over N=2 workers, same
    // profiles, same deterministic descent — the plan must not drift
    let mid = &specs[1];
    let inproc = runner.plan_budget(mid)?;
    let sharded = {
        let mut session = ShardSession::spawn(&ShardOptions::with_workers(2))?;
        let sharded_runner = ShardedSweepRunner::new(&fx.params, &fx.cfg, &fx.calib, &metrics);
        let plan = sharded_runner.plan_budget(&mut session, mid)?;
        session.shutdown();
        plan
    };
    let allocation_bit_identical = inproc == sharded && inproc == plans[1].0;

    let mut allocated_beats_uniform = true;
    let mut plans_fit_budget = true;
    let mut planned_k_realized = true;
    let mut point_records = Vec::new();
    let mut table = Table::new(
        "§Budget allocated vs uniform PPL at equal bytes (BENCH_budget.json)",
        &["budget bytes", "uniform cell", "uniform ppl", "allocated ppl", "Δppl"],
    );
    for (i, (alloc, uni)) in plans.iter().enumerate() {
        let (ao, uo) = (&outs[2 * i], &outs[2 * i + 1]);
        for (plan, out) in [(alloc, ao), (uni, uo)] {
            planned_k_realized &= plan
                .layers
                .iter()
                .zip(&out.meta)
                .all(|(l, m)| l.name == m.name && l.k == m.k_star);
        }
        let ppl_alloc = perplexity_native(&ao.model, &fx.cfg, &batches, b, t_len);
        let ppl_uni = perplexity_native(&uo.model, &fx.cfg, &batches, b, t_len);
        plans_fit_budget &= alloc.plan_bytes <= points[i] && uni.plan_bytes <= points[i];
        // ties count for the allocator: equal PPL at equal bytes is "no
        // worse", and the eval is deterministic (the epsilon only
        // absorbs non-associative reduction orderings, not noise)
        allocated_beats_uniform &= ppl_alloc <= ppl_uni + 1e-9;
        let cell = format!("mxint{}/r{}", uni.layers[0].bits, uni.layers[0].rank);
        table.row(vec![
            format!("{}", points[i]),
            cell.clone(),
            f(ppl_uni, 4),
            f(ppl_alloc, 4),
            f(ppl_alloc - ppl_uni, 4),
        ]);
        point_records.push(Json::obj(vec![
            ("budget_bytes", Json::num(points[i] as f64)),
            ("allocated_bytes", Json::num(alloc.plan_bytes as f64)),
            ("allocated_predicted_err2", Json::num(alloc.predicted_err2)),
            ("allocated_ppl", Json::num(ppl_alloc)),
            ("uniform_cell", Json::str(cell)),
            ("uniform_bytes", Json::num(uni.plan_bytes as f64)),
            ("uniform_predicted_err2", Json::num(uni.predicted_err2)),
            ("uniform_ppl", Json::num(ppl_uni)),
        ]));
    }

    let record = Json::obj(vec![
        ("model", Json::str(model)),
        ("quick", Json::Bool(ctx.quick)),
        ("n_layers", Json::num(profiles.len() as f64)),
        ("bf16_ppl", Json::num(bf16_ppl)),
        ("profile_secs", Json::num(profile_secs)),
        ("run_secs", Json::num(run_secs)),
        ("points", Json::arr(point_records)),
        ("plans_fit_budget", Json::Bool(plans_fit_budget)),
        ("planned_k_realized", Json::Bool(planned_k_realized)),
        ("allocated_beats_uniform", Json::Bool(allocated_beats_uniform)),
        ("allocation_bit_identical", Json::Bool(allocation_bit_identical)),
    ]);
    // written before the gates below so a divergence still lands in the
    // record for check_bench.py to flag
    bench::write_json("BENCH_budget.json", &record)?;

    anyhow::ensure!(plans_fit_budget, "a plan exceeded its byte budget");
    anyhow::ensure!(planned_k_realized, "planned preserve-k diverged from the realized k*");
    anyhow::ensure!(
        allocated_beats_uniform,
        "allocated plan lost to the uniform baseline at equal bytes"
    );
    anyhow::ensure!(
        allocation_bit_identical,
        "sharded budget plan diverged from the in-process plan"
    );
    Ok(vec![table])
}
