//! Shared experiment fixtures: engine + per-model (params, corpus,
//! calibration) caches, plus the size knobs that distinguish `quick`
//! smoke runs from the full recorded runs.

use std::collections::HashMap;
use std::rc::Rc;

use anyhow::Result;

use crate::data::Corpus;
use crate::model::{collect_calibration, synth_lm_params, CalibrationSet, Params};
use crate::qpeft::AdamW;
use crate::runtime::manifest::ModelCfg;
use crate::runtime::{Engine, Executor, TensorValue};
use crate::tensor::Mat;

pub struct LmFixture {
    pub cfg: ModelCfg,
    pub params: Params,
    pub corpus: Corpus,
    pub calib: CalibrationSet,
}

pub struct ExpCtx {
    pub engine: Engine,
    pub quick: bool,
    /// base seed for the whole suite (paper: mean±std over 3 seeds)
    pub seed: u64,
    fixtures: HashMap<String, Rc<LmFixture>>,
}

/// Built-in manifest mirroring python/compile/configs.py, for engine-free
/// experiments (the sweep bench, CI smoke) when no `artifacts/` exists.
/// No artifacts are listed, so fixtures skip training and anything that
/// executes an artifact keeps failing with a clear error.
const OFFLINE_MANIFEST: &str = r#"{
  "models": {
    "tiny":  {"vocab": 256,  "d_model": 128, "n_heads": 4, "n_layers": 2,
              "d_ff": 512,  "seq_len": 64},
    "small": {"vocab": 1024, "d_model": 256, "n_heads": 8, "n_layers": 4,
              "d_ff": 1024, "seq_len": 128},
    "base":  {"vocab": 2048, "d_model": 384, "n_heads": 8, "n_layers": 6,
              "d_ff": 1536, "seq_len": 128}
  },
  "constants": {"lm_batch": 8, "cls_batch": 16, "cls_seq": 32, "cls_classes": 4},
  "artifacts": []
}"#;

impl ExpCtx {
    pub fn new(quick: bool) -> Result<Self> {
        Ok(ExpCtx { engine: Engine::discover()?, quick, seed: 0, fixtures: HashMap::new() })
    }

    /// Engine-free context: model configs from the embedded manifest,
    /// calibration through the rust-native forward, no PJRT. Experiments
    /// flagged `offline_ok` in the registry run under this.
    ///
    /// Caveat: under `--features pjrt` this still constructs the PJRT
    /// client (and fails against the vendored stub) — offline mode is
    /// for the default build; making `ExpCtx` engine-optional is future
    /// work.
    pub fn offline(quick: bool) -> Result<Self> {
        let manifest = crate::runtime::Manifest::parse(
            OFFLINE_MANIFEST,
            std::path::PathBuf::from("offline"),
        )?;
        Ok(ExpCtx { engine: Engine::new(manifest)?, quick, seed: 0, fixtures: HashMap::new() })
    }

    /// Paper setting: three random seeds for SRR's probe (§5.1).
    pub fn srr_seeds(&self) -> Vec<u64> {
        if self.quick {
            vec![self.seed]
        } else {
            vec![self.seed, self.seed + 1, self.seed + 2]
        }
    }

    /// Number of held-out eval batches for PPL.
    pub fn eval_batches(&self) -> usize {
        if self.quick {
            2
        } else {
            6
        }
    }

    pub fn calib_rows(&self, cfg: &ModelCfg) -> usize {
        // at least 2x the widest Gram (d_ff) so exact scaling is full rank
        let base = 2 * cfg.d_ff;
        if self.quick {
            base
        } else {
            (2 * cfg.d_ff).max(256)
        }
    }

    /// Training steps for the fixture model (0 = keep synthetic weights,
    /// used for the structure-only analyses on `base`, which has no
    /// train artifact by design — see DESIGN.md §2).
    fn train_steps(&self, model: &str) -> usize {
        let full = match model {
            "tiny" => 400,
            "small" => 220,
            _ => 0,
        };
        if self.quick {
            full.min(60)
        } else {
            full
        }
    }

    /// Build (or fetch) the fixture for a model in the manifest.
    ///
    /// The spiky synthetic init only shapes the starting spectra; models
    /// with a `lm_train_*` artifact are then actually *trained* on the
    /// corpus (rust AdamW over the AOT value-and-grad graph) so that the
    /// PPL experiments measure a fitted model — quantization must damage
    /// it and QER/SRR must recover it, the paper's Table 1 dynamic.
    pub fn lm(&mut self, model: &str) -> Result<Rc<LmFixture>> {
        if let Some(f) = self.fixtures.get(model) {
            return Ok(f.clone());
        }
        let cfg = self.engine.manifest().model(model)?.clone();
        let mut params = synth_lm_params(&cfg, 1000 + self.seed, cfg.vocab);
        let corpus = Corpus::generate(cfg.vocab, 60_000.max(cfg.seq_len * 400), 2000 + self.seed);
        let b = self.engine.manifest().lm_batch;

        let steps = self.train_steps(model);
        let train_artifact = format!("lm_train_{model}");
        if steps > 0 && self.engine.manifest().artifacts.contains_key(&train_artifact) {
            train_lm(&self.engine, &cfg, &mut params, &corpus, &train_artifact, b, steps, 3e-3)?;
        }

        let rows = self.calib_rows(&cfg);
        let n_batches = rows.div_ceil(b * cfg.seq_len) + 1;
        let batches: Vec<Vec<i32>> =
            (0..n_batches).map(|i| corpus.train_batch(b, cfg.seq_len, 90_000 + i)).collect();
        let calib = collect_calibration(&params, &cfg, &batches, b, cfg.seq_len, rows);
        let fixture = Rc::new(LmFixture { cfg, params, corpus, calib });
        self.fixtures.insert(model.to_string(), fixture.clone());
        Ok(fixture)
    }

    /// Held-out eval token batches for a model.
    pub fn ppl_batches(&mut self, model: &str) -> Result<Vec<Vec<i32>>> {
        let f = self.lm(model)?;
        let b = self.engine.manifest().lm_batch;
        let mut batches = f.corpus.eval_batches(b, f.cfg.seq_len);
        batches.truncate(self.eval_batches());
        Ok(batches)
    }
}

/// Train `params` in place through the AOT `lm_train_*` artifact
/// (full-parameter AdamW in rust). Shared by the fixtures and the
/// end-to-end example.
#[allow(clippy::too_many_arguments)]
pub fn train_lm(
    engine: &Engine,
    cfg: &ModelCfg,
    params: &mut Params,
    corpus: &Corpus,
    train_artifact: &str,
    b: usize,
    steps: usize,
    lr: f32,
) -> Result<(f32, f32)> {
    let order = Params::param_order(cfg);
    let mut mats: Vec<Mat> = order
        .iter()
        .map(|n| {
            let v = params.get(n).unwrap();
            let sh = v.shape();
            if sh.len() == 1 {
                Mat::from_vec(1, sh[0], v.as_f32().to_vec())
            } else {
                v.to_mat()
            }
        })
        .collect();
    let mut opt = AdamW::for_mats(lr, &mats.iter().collect::<Vec<_>>());
    opt.weight_decay = 0.0;
    let mut first = f32::NAN;
    let mut last = f32::NAN;
    for step in 0..steps {
        let mut inputs: Vec<TensorValue> = order
            .iter()
            .zip(&mats)
            .map(|(n, m)| {
                TensorValue::f32(Params::param_shape(n, cfg, cfg.vocab), m.data.clone())
            })
            .collect();
        inputs.push(TensorValue::i32(vec![b, cfg.seq_len], corpus.train_batch(b, cfg.seq_len, step)));
        let outs = engine.run(train_artifact, &inputs)?;
        let loss = outs[0].scalar();
        if step == 0 {
            first = loss;
        }
        last = loss;
        let grads: Vec<Mat> = outs[1..]
            .iter()
            .zip(&mats)
            .map(|(g, m)| Mat::from_vec(m.rows, m.cols, g.as_f32().to_vec()))
            .collect();
        let grad_refs: Vec<&Mat> = grads.iter().collect();
        let mut mat_refs: Vec<&mut Mat> = mats.iter_mut().collect();
        opt.update(&mut mat_refs, &grad_refs);
    }
    for (n, m) in order.iter().zip(&mats) {
        params.set(n, TensorValue::f32(Params::param_shape(n, cfg, cfg.vocab), m.data.clone()));
    }
    eprintln!("  [fixture {}: trained {steps} steps, loss {first:.3} -> {last:.3}]", cfg.name);
    Ok((first, last))
}
