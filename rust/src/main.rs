//! `srr` — the Layer-3 coordinator CLI.
//!
//! Subcommands:
//!   info                       — artifact/model inventory
//!   ptq    [--model --method --scaling --quantizer --rank --seed]
//!          [--workers N | --workers tcp:host:port,... | --listen host:port]
//!          [--heartbeat-timeout S] [--spill DIR [--spill-cap-mb N]]
//!                              — quantize a model, report per-layer stats + PPL
//!                                (runs offline: rust-native factored eval;
//!                                --workers N spawns local worker processes,
//!                                --workers tcp:… dials listening remote
//!                                workers, --listen waits for remote workers
//!                                to dial in; --heartbeat-timeout tunes how
//!                                long a silent worker may go before being
//!                                declared wedged and its jobs requeued;
//!                                --spill DIR streams sweep artifacts
//!                                through a disk store bounded to
//!                                --spill-cap-mb of memory, and resumes
//!                                a killed run from DIR's manifest)
//!   budget [--model --gigabytes G | --budget-bytes N]
//!          [--bits 2,3,4] [--ranks 0,4,8,16,32] [--block 32] [--seed S]
//!          [--plan-out FILE] [shard + spill flags as in ptq]
//!                              — allocate a model-wide byte budget into
//!                                per-layer (bits, rank, k), print/emit the
//!                                plan (a wire-codec BUDGET_PLAN frame),
//!                                then run the allocated PTQ and report
//!                                PPL vs BF16 (runs offline)
//!   qpeft  [--task --init --bits --steps --gamma]
//!                              — fine-tune adapters on a GLUE-sim task
//!   bench  [ids… | --list] [--quick]
//!                              — regenerate paper tables/figures
//!   shard-worker [--exit-after N] [--heartbeat-secs S]
//!                [--connect host:port [--token N] | --listen host:port]
//!                              — wire-codec job executor over stdin/stdout
//!                                (spawned by the shard host) or over a
//!                                handshaken TCP connection (remote workers;
//!                                not for interactive use). `--connect` may
//!                                also join a host *mid-run*: an elastic host
//!                                keeps its accept loop open and feeds
//!                                late joiners from the live job queue
//!   serve  [--model M] [--listen host:port] [--ranks 4,8] [--slots N]
//!          [--batch N] [--quick]
//!                              — continuous-batching inference daemon:
//!                                quantizes M into several rank variants
//!                                sharing one packed base and serves them
//!                                behind one endpoint (runs offline)
//!   client --connect host:port [--variant NAME] [--prompt 1,2,3]
//!          [--max-new N | --score]
//!                              — one-shot serving client for `srr serve`
//!
//! Examples live in `examples/` (quickstart, ptq_sweep, qpeft_finetune,
//! e2e_train_quantize, shard_sweep).

use anyhow::Result;

use srr::coordinator::{
    fleet_perplexity_sharded, outcome_content_hash, run_ptq_factored, run_sweep_spilled,
    BudgetSpec, Metrics, RunConfig, ShardOptions, ShardSession, ShardedSweepRunner,
    SpillOptions, SpillStore, SweepConfig, SweepRunner,
};
use srr::serve::daemon::{Daemon, DaemonConfig, FleetEngine, ServeClient};
use srr::data::glue_sim::GlueTask;
use srr::eval::{glue_score, perplexity_native};
use srr::exp::{registry, ExpCtx};
use srr::qpeft::{init_qpeft, GradScale, QpeftInit, QpeftTrainer};
use srr::runtime::{Engine, Executor, TensorValue};
use srr::tensor::Mat;
use srr::util::bench::f;
use srr::util::cli::Args;
use srr::util::Rng;

fn main() {
    let args = Args::from_env();
    let result = match args.subcommand.as_deref() {
        Some("info") => cmd_info(),
        Some("ptq") => cmd_ptq(&args),
        Some("qpeft") => cmd_qpeft(&args),
        Some("bench") => cmd_bench(&args),
        // spawned by ShardSession with piped stdio; speaks coordinator::wire
        Some("shard-worker") => srr::coordinator::worker_main(&args),
        Some("serve") => cmd_serve(&args),
        Some("client") => cmd_client(&args),
        Some("budget") => cmd_budget(&args),
        _ => {
            eprintln!(
                "usage: srr <info|ptq|budget|qpeft|bench|shard-worker|serve|client> [options]\n\
                 \n  srr info\
                 \n  srr ptq --model small --method srr --scaling qera-exact --quantizer mxint3 --rank 8\
                 \n  srr ptq --model tiny --rank 8 --workers 2   # multi-process reconstruction + eval\
                 \n  srr ptq --model tiny --rank 8 --spill /tmp/sweep   # out-of-core, kill-resumable\
                 \n  srr ptq --model tiny --rank 8 --listen 127.0.0.1:7777 --workers 2   # remote workers dial in\
                 \n  srr shard-worker --connect host:7777        # remote worker side\
                 \n  srr budget --model tiny --gigabytes 0.002 --bits 2,3,4 --ranks 0,4,8 --plan-out plan.srrw\
                 \n  srr qpeft --task SST-sim --init srr --bits 2 --steps 60\
                 \n  srr bench table1 fig5 [--quick]   |   srr bench --list\
                 \n  srr serve --model tiny --listen 127.0.0.1:7878 --ranks 4,8   # batching daemon\
                 \n  srr client --connect 127.0.0.1:7878 --variant r8 --prompt 3,1,4,1,5 --max-new 8"
            );
            Ok(())
        }
    };
    if let Err(e) = result {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn cmd_info() -> Result<()> {
    let engine = Engine::discover()?;
    let m = engine.manifest();
    println!("artifacts dir: {}", m.dir.display());
    println!("\nmodels:");
    for (name, cfg) in &m.models {
        let params: usize = srr::model::Params::param_order(cfg)
            .iter()
            .map(|n| {
                srr::model::Params::param_shape(n, cfg, cfg.vocab).iter().product::<usize>()
            })
            .sum();
        println!(
            "  {name:6} vocab={:5} d={:4} heads={} layers={} ff={:5} seq={:4}  ~{:.1}M params",
            cfg.vocab, cfg.d_model, cfg.n_heads, cfg.n_layers, cfg.d_ff, cfg.seq_len,
            params as f64 / 1e6
        );
    }
    println!("\nartifacts ({}):", m.artifacts.len());
    for (name, a) in &m.artifacts {
        println!("  {name:32} args={:3} outputs={}", a.args.len(), a.outputs.len());
    }
    Ok(())
}

fn cmd_ptq(args: &Args) -> Result<()> {
    let cfg = RunConfig::from_args(args)?;
    // no artifacts? fall back to the embedded offline manifest — the
    // factored pipeline and the rust-native PPL below need no PJRT
    let mut ctx = match ExpCtx::new(args.has_flag("quick")) {
        Ok(ctx) => ctx,
        Err(e) => {
            eprintln!("[no artifacts ({e:#}); offline mode — untrained synthetic fixture]");
            ExpCtx::offline(args.has_flag("quick"))?
        }
    };
    ctx.seed = cfg.seed;
    println!(
        "PTQ: model={} method={} scaling={:?} quantizer={} rank={}",
        cfg.model,
        cfg.method.label(),
        cfg.scaling,
        cfg.quantizer.label(),
        cfg.rank
    );
    let fx = ctx.lm(&cfg.model)?;
    let metrics = Metrics::new();
    let mut session = session_from_args(args)?;
    let spill = spill_store_from_args(args)?;
    let out = if let Some(session) = session.as_mut() {
        let sweep_cfg = SweepConfig::new(cfg.quantizer, cfg.method, cfg.rank, cfg.scaling)
            .seeded(cfg.seed);
        let runner = ShardedSweepRunner::new(&fx.params, &fx.cfg, &fx.calib, &metrics);
        let mut outs = if let Some(store) = spill.as_ref() {
            runner.run_factored_spilled(session, &[sweep_cfg], store)?
        } else {
            runner.run_factored(session, &[sweep_cfg])?
        };
        outs.pop().expect("one outcome for one config")
    } else if let Some(store) = spill.as_ref() {
        let sweep_cfg = SweepConfig::new(cfg.quantizer, cfg.method, cfg.rank, cfg.scaling)
            .seeded(cfg.seed);
        run_sweep_spilled(&fx.params, &fx.cfg, &fx.calib, &[sweep_cfg], &metrics, store)?
            .pop()
            .expect("one outcome for one config")
    } else {
        let mut qcfg = srr::qer::QerConfig::new(cfg.method, cfg.rank, cfg.scaling);
        qcfg.seed = cfg.seed;
        run_ptq_factored(&fx.params, &fx.cfg, &fx.calib, cfg.quantizer, &qcfg, &metrics)
    };
    if spill.is_some() {
        // stable across in-process / sharded / killed-and-resumed runs;
        // the kill-and-resume harness compares these lines bit-exactly
        println!("spill outcome hash = {:032x}", outcome_content_hash(&out));
    }
    println!("\nper-layer:");
    for r in &out.reports {
        println!(
            "  {:10} k*={:3} weight_err={:8.4} scaled_err={:8.4} ({:.0} ms)",
            r.name,
            r.k_star,
            r.weight_err,
            r.scaled_err,
            (r.scale_secs + r.qer_secs) * 1e3
        );
    }
    let b = ctx.engine.manifest().lm_batch;
    let t = fx.cfg.seq_len;
    let batches = ctx.ppl_batches(&cfg.model)?;
    // rust-native eval: the BF16 reference densely, the outcome straight
    // through its factored serving form (packed bases never densified);
    // under --workers the outcome PPL runs on the shard workers too
    let bf16 = perplexity_native(&fx.params, &fx.cfg, &batches, b, t);
    let ppl = if let Some(session) = session.as_mut() {
        fleet_perplexity_sharded(session, &[&out.model], &fx.cfg, &batches, b, t, &metrics)?[0]
    } else {
        perplexity_native(&out.model, &fx.cfg, &batches, b, t)
    };
    if let Some(session) = session.take() {
        session.shutdown();
    }
    println!(
        "\nBF16 PPL = {bf16:.3}   quantized PPL = {ppl:.3}   mean k* = {:.1}   \
         serving bytes = {} (dense {})",
        out.mean_k_star(),
        out.model.linear_bytes(),
        out.model.dense_linear_bytes()
    );
    println!("\n{}", metrics.report());
    Ok(())
}

/// The shared sharding flags (`srr ptq` / `srr budget`), all modes
/// bit-identical to the in-process path:
///   --workers N                 spawn N local `srr shard-worker`
///                               processes over pipes;
///   --workers tcp:host:port,…   dial workers already listening
///                               (`srr shard-worker --listen …`);
///   --listen host:port          wait for --workers N (default 1)
///                               remote workers to dial in
///                               (`srr shard-worker --connect …`).
///
/// `--heartbeat-timeout S`: a worker whose in-flight jobs go silent for
/// S seconds is declared wedged — its jobs requeue onto live workers.
/// Over WANs with long GC/paging pauses, raise it; the default (10 s)
/// suits LAN and local-pipe fleets. `worker_threads: 0` lets each local
/// worker size its own pool (SRR_THREADS / available cores); the
/// single-threaded pinning is only for the scaling bench.
///
/// `--spill DIR` (with `--spill-cap-mb N`, default 256): stream sweep
/// artifacts through a disk-backed store rooted at DIR instead of
/// holding the whole grid in memory, keeping at most N MiB of reloaded
/// blobs resident. DIR doubles as a crash-resume manifest: re-running
/// the same sweep with the same `--spill DIR` skips every chunk that
/// already completed. Returns None when no spilling was requested.
fn spill_store_from_args(args: &Args) -> Result<Option<SpillStore>> {
    let Some(dir) = args.get("spill") else {
        return Ok(None);
    };
    let cap_mb = args.get_usize("spill-cap-mb", 256);
    anyhow::ensure!(cap_mb > 0, "--spill-cap-mb must be > 0");
    let opts = SpillOptions { cap_bytes: cap_mb << 20, ..Default::default() };
    Ok(Some(SpillStore::open(dir, opts)?))
}

/// Returns None when no sharding was requested.
fn session_from_args(args: &Args) -> Result<Option<ShardSession>> {
    let heartbeat_timeout = match args.get("heartbeat-timeout") {
        Some(spec) => {
            let secs: f64 = spec.parse().map_err(|_| {
                anyhow::anyhow!("--heartbeat-timeout expects seconds, got {spec:?}")
            })?;
            anyhow::ensure!(secs > 0.0, "--heartbeat-timeout must be > 0");
            Some(std::time::Duration::from_secs_f64(secs))
        }
        None => None,
    };
    if let Some(addr) = args.get("listen") {
        // an unparseable or zero count must not silently turn into the
        // default (pipe mode gives --workers 0 a different meaning)
        let n = match args.get("workers") {
            Some(spec) => {
                let n: usize = spec.parse().map_err(|_| {
                    anyhow::anyhow!("--listen expects --workers N (a count), got {spec:?}")
                })?;
                anyhow::ensure!(n >= 1, "--listen needs --workers ≥ 1");
                n
            }
            None => 1,
        };
        let deadline = std::time::Duration::from_secs(args.get_u64("accept-timeout", 120));
        println!("listening on {addr} for {n} remote worker(s)…");
        let mut session = ShardSession::listen(addr, n, deadline)?;
        if let Some(t) = heartbeat_timeout {
            session.set_heartbeat_timeout(t);
        }
        Ok(Some(session))
    } else if let Some(spec) = args.get("workers") {
        if spec.contains("tcp:") {
            // every entry must parse — a silently dropped worker address
            // would shrink the fleet without anyone noticing
            let addrs: Vec<String> = spec
                .split(',')
                .map(|s| {
                    s.trim()
                        .strip_prefix("tcp:")
                        .filter(|a| !a.is_empty())
                        .map(str::to_string)
                        .ok_or_else(|| {
                            anyhow::anyhow!("--workers entry {s:?} is not tcp:host:port")
                        })
                })
                .collect::<Result<_>>()?;
            println!("dialing {} remote worker(s)…", addrs.len());
            let mut session = ShardSession::dial(&addrs)?;
            if let Some(t) = heartbeat_timeout {
                session.set_heartbeat_timeout(t);
            }
            Ok(Some(session))
        } else {
            let workers: usize = spec
                .parse()
                .map_err(|_| anyhow::anyhow!("--workers expects a count or tcp:host:port list"))?;
            if workers > 0 {
                let mut opts =
                    ShardOptions { workers, worker_threads: 0, ..Default::default() };
                if let Some(t) = heartbeat_timeout {
                    // set before spawn so the workers' --heartbeat-secs
                    // cadence is derived from the same timeout
                    opts.heartbeat_timeout = t;
                }
                Ok(Some(ShardSession::spawn(&opts)?))
            } else {
                Ok(None)
            }
        }
    } else {
        Ok(None)
    }
}

fn cmd_budget(args: &Args) -> Result<()> {
    let model = args.get_or("model", "tiny").to_string();
    let mut ctx = match ExpCtx::new(args.has_flag("quick")) {
        Ok(ctx) => ctx,
        Err(e) => {
            eprintln!("[no artifacts ({e:#}); offline mode — untrained synthetic fixture]");
            ExpCtx::offline(args.has_flag("quick"))?
        }
    };

    let mut spec = if let Some(g) = args.get("gigabytes") {
        let g: f64 = g
            .parse()
            .map_err(|_| anyhow::anyhow!("--gigabytes expects a number, got {g:?}"))?;
        anyhow::ensure!(g > 0.0, "--gigabytes must be > 0");
        BudgetSpec::gigabytes(g)
    } else if let Some(b) = args.get("budget-bytes") {
        let b: u64 = b
            .parse()
            .map_err(|_| anyhow::anyhow!("--budget-bytes expects an integer, got {b:?}"))?;
        BudgetSpec::new(b)
    } else {
        anyhow::bail!("srr budget needs --gigabytes G or --budget-bytes N");
    };
    if let Some(list) = args.get("bits") {
        spec.bits_choices = list
            .split(',')
            .map(|s| {
                s.trim()
                    .parse()
                    .map_err(|_| anyhow::anyhow!("--bits expects a comma list, got {s:?}"))
            })
            .collect::<Result<_>>()?;
    }
    if let Some(list) = args.get("ranks") {
        spec.rank_choices = list
            .split(',')
            .map(|s| {
                s.trim()
                    .parse()
                    .map_err(|_| anyhow::anyhow!("--ranks expects a comma list, got {s:?}"))
            })
            .collect::<Result<_>>()?;
    }
    spec.block = args.get_usize("block", spec.block);
    spec.seed = args.get_u64("seed", 0);
    ctx.seed = spec.seed;

    let fx = ctx.lm(&model)?;
    let metrics = Metrics::new();
    println!(
        "budget: model={model} budget={} bytes bits={:?} ranks={:?} block={}",
        spec.budget_bytes, spec.bits_choices, spec.rank_choices, spec.block
    );

    let mut session = session_from_args(args)?;
    let runner = SweepRunner::new(&fx.params, &fx.cfg, &fx.calib, &metrics);
    let sharded = ShardedSweepRunner::new(&fx.params, &fx.cfg, &fx.calib, &metrics);
    let plan = if let Some(session) = session.as_mut() {
        sharded.plan_budget(session, &spec)?
    } else {
        runner.plan_budget(&spec)?
    };

    println!(
        "\nplan: {} of {} bytes, predicted err² = {:.4e}",
        plan.plan_bytes, plan.budget_bytes, plan.predicted_err2
    );
    println!("per-layer:");
    for l in &plan.layers {
        println!(
            "  {:10} {}b rank={:3} k={:3} {:>10} B  err²={:.3e}",
            l.name, l.bits, l.rank, l.k, l.bytes, l.predicted_err2
        );
    }
    if let Some(path) = args.get("plan-out") {
        let frame = srr::coordinator::wire::encode_budget_plan(&plan);
        let mut file = std::fs::File::create(path)?;
        frame.write_to(&mut file)?;
        println!("plan frame written to {path}");
    }

    // run the allocated PTQ and score it; planning stays in-memory (it
    // only holds phase-A profiles), the allocated sweep itself streams
    // through --spill when given
    let configs = [plan.sweep_config()];
    let spill = spill_store_from_args(args)?;
    let out = if let Some(session) = session.as_mut() {
        if let Some(store) = spill.as_ref() {
            sharded
                .run_factored_spilled(session, &configs, store)?
                .pop()
                .expect("one outcome for one config")
        } else {
            sharded
                .run_factored(session, &configs)?
                .pop()
                .expect("one outcome for one config")
        }
    } else if let Some(store) = spill.as_ref() {
        run_sweep_spilled(&fx.params, &fx.cfg, &fx.calib, &configs, &metrics, store)?
            .pop()
            .expect("one outcome for one config")
    } else {
        runner.run_factored(&configs).pop().expect("one outcome for one config")
    };
    if spill.is_some() {
        println!("spill outcome hash = {:032x}", outcome_content_hash(&out));
    }
    let b = ctx.engine.manifest().lm_batch;
    let t = fx.cfg.seq_len;
    let batches = ctx.ppl_batches(&model)?;
    let bf16 = perplexity_native(&fx.params, &fx.cfg, &batches, b, t);
    let ppl = if let Some(session) = session.as_mut() {
        fleet_perplexity_sharded(session, &[&out.model], &fx.cfg, &batches, b, t, &metrics)?[0]
    } else {
        perplexity_native(&out.model, &fx.cfg, &batches, b, t)
    };
    if let Some(session) = session.take() {
        session.shutdown();
    }
    println!(
        "\nBF16 PPL = {bf16:.3}   allocated PPL = {ppl:.3}   mean k* = {:.1}   \
         serving bytes = {} (dense {})",
        out.mean_k_star(),
        out.model.linear_bytes(),
        out.model.dense_linear_bytes()
    );
    Ok(())
}

fn cmd_qpeft(args: &Args) -> Result<()> {
    let mut ctx = ExpCtx::new(args.has_flag("quick"))?;
    let task_name = args.get_or("task", "SST-sim").to_string();
    let bits: u32 = args.get_usize("bits", 2) as u32;
    let steps = args.get_usize("steps", 60);
    let gamma = args.get_f64("gamma", 0.1) as f32;
    let init = match args.get_or("init", "srr") {
        "qlora" => QpeftInit::QLoRA,
        "loftq" => QpeftInit::LoftQ { iters: 5 },
        "lqlora" => QpeftInit::LqLora { iters: 5 },
        "qera" => QpeftInit::Qera,
        "lora" => QpeftInit::LoRA,
        _ => QpeftInit::Srr,
    };
    let rank = if bits == 2 { 64 } else { 8 };
    let scale = if init == QpeftInit::Srr {
        GradScale::Fixed { gamma }
    } else {
        GradScale::None
    };

    let m = ctx.engine.manifest();
    let (batch, seq, classes) = (m.cls_batch, m.cls_seq, m.cls_classes);
    let vocab = m.model("tiny")?.vocab;
    let tasks = GlueTask::all(vocab, seq, 256, 64, 9090);
    let task = tasks
        .iter()
        .find(|t| t.name == task_name)
        .ok_or_else(|| anyhow::anyhow!("unknown task {task_name}"))?
        .clone();
    let fx = ctx.lm("tiny")?;
    let quant = srr::coordinator::QuantizerSpec::Mxint { bits, block: 32 };
    let mut rng = Rng::new(777);
    let head = Mat::randn(fx.cfg.d_model, classes, 0.02, &mut rng);
    let state = init_qpeft(&fx.params, &fx.cfg, &fx.calib, quant, init, rank, head, 0);
    println!(
        "QPEFT: task={} init={} bits={bits} rank={rank} scale={} trainable={}",
        task.name,
        init.label(),
        scale.label(),
        state.trainable_count()
    );
    let mut trainer = QpeftTrainer::new(
        &ctx.engine,
        &format!("qpeft_cls_train_tiny_r{rank}"),
        state,
        1e-3,
        scale,
    );
    for step in 0..steps {
        let (toks, labels, _) = GlueTask::batch(&task.train, step * batch, batch, seq);
        let loss = trainer.step(&[
            TensorValue::i32(vec![batch, seq], toks),
            TensorValue::i32(vec![batch], labels),
        ])?;
        if step % 10 == 0 || step + 1 == steps {
            println!("  step {step:4}  loss {loss:.4}");
        }
    }
    // dev eval
    let n_out = classes;
    let mut logits = vec![0.0f32; task.dev.len() * n_out];
    let mut i = 0;
    while i < task.dev.len() {
        let (toks, _, _) = GlueTask::batch(&task.dev, i, batch, seq);
        let out = trainer.eval(
            &format!("qpeft_cls_fwd_tiny_r{rank}"),
            &[TensorValue::i32(vec![batch, seq], toks)],
        )?;
        let data = out.as_f32();
        for row in 0..batch {
            if i + row < task.dev.len() {
                logits[(i + row) * n_out..(i + row + 1) * n_out]
                    .copy_from_slice(&data[row * n_out..(row + 1) * n_out]);
            }
        }
        i += batch;
    }
    let score = glue_score(task.metric, &logits, n_out, &task.dev);
    println!("dev score: {}", f(score, 2));
    Ok(())
}

fn cmd_serve(args: &Args) -> Result<()> {
    let model = args.get_or("model", "tiny").to_string();
    let listen = args.get_or("listen", "127.0.0.1:7878").to_string();
    let ranks: Vec<usize> = args
        .get_or("ranks", "4,8")
        .split(',')
        .map(|s| {
            s.trim()
                .parse()
                .map_err(|_| anyhow::anyhow!("--ranks expects a comma list of ranks, got {s:?}"))
        })
        .collect::<Result<_>>()?;
    anyhow::ensure!(!ranks.is_empty(), "--ranks must name at least one rank");
    let mut ctx = match ExpCtx::new(args.has_flag("quick")) {
        Ok(ctx) => ctx,
        Err(e) => {
            eprintln!("[no artifacts ({e:#}); offline mode — untrained synthetic fixture]");
            ExpCtx::offline(args.has_flag("quick"))?
        }
    };
    let fx = ctx.lm(&model)?;
    let metrics = Metrics::new();
    // one quantizer/seed across ranks → every variant shares the same
    // Arc<PackedMat> base per linear, so mixed batches decode each base
    // once (the whole point of serving a rank family together)
    let quant = srr::coordinator::QuantizerSpec::Mxint { bits: 4, block: 32 };
    let configs: Vec<SweepConfig> = ranks
        .iter()
        .map(|&r| {
            SweepConfig::new(quant, srr::qer::Method::Qer, r, srr::scaling::ScalingKind::DiagRms)
                .labeled(&format!("r{r}"))
        })
        .collect();
    println!("quantizing {model} into {} rank variant(s)…", configs.len());
    let outs = SweepRunner::new(&fx.params, &fx.cfg, &fx.calib, &metrics).run_factored(&configs);
    let variants: Vec<(String, srr::serve::FactoredModel)> = configs
        .iter()
        .zip(outs)
        .map(|(c, o)| (c.label.clone(), o.model))
        .collect();
    let engine = FleetEngine::new(fx.cfg.clone(), variants)?;
    let cfg = DaemonConfig {
        max_slots: args.get_usize("slots", 16),
        max_batch: args.get_usize("batch", 8),
        ..Default::default()
    };
    let names: Vec<String> = engine.variant_names().iter().map(|s| s.to_string()).collect();
    let mut daemon = Daemon::new(engine, cfg);
    let bound = daemon.bind(&listen)?;
    println!("serving variants [{}] on {bound}", names.join(", "));
    let handle = daemon.spawn();
    // foreground stats ticker; the daemon itself runs on its own threads
    loop {
        std::thread::sleep(std::time::Duration::from_secs(5));
        let s = handle.stats();
        println!(
            "active={} served={} busy={} refused={} malformed={} disconnects={}",
            s.active_slots.load(std::sync::atomic::Ordering::Relaxed),
            s.served.load(std::sync::atomic::Ordering::Relaxed),
            s.shed.load(std::sync::atomic::Ordering::Relaxed),
            s.refused.load(std::sync::atomic::Ordering::Relaxed),
            s.malformed.load(std::sync::atomic::Ordering::Relaxed),
            s.disconnects.load(std::sync::atomic::Ordering::Relaxed),
        );
    }
}

fn cmd_client(args: &Args) -> Result<()> {
    let addr = args
        .get("connect")
        .ok_or_else(|| anyhow::anyhow!("srr client needs --connect host:port"))?;
    let variant = args.get_or("variant", "r8").to_string();
    let tokens: Vec<i32> = args
        .get_or("prompt", "1,2,3,4")
        .split(',')
        .map(|s| {
            s.trim()
                .parse()
                .map_err(|_| anyhow::anyhow!("--prompt expects comma-separated token ids"))
        })
        .collect::<Result<_>>()?;
    let mut client = ServeClient::dial(addr, &variant)?;
    let reply = if args.has_flag("score") {
        client.score(&tokens)?
    } else {
        client.generate(&tokens, args.get_usize("max-new", 8))?
    };
    match reply {
        srr::serve::daemon::ServeReply::Tokens { tokens, .. } => {
            println!(
                "generated: {}",
                tokens.iter().map(|t| t.to_string()).collect::<Vec<_>>().join(",")
            );
        }
        srr::serve::daemon::ServeReply::Score { nll, count, .. } => {
            println!("nll = {nll:.4} over {count} positions (ppl {:.3})", (nll / count).exp());
        }
        srr::serve::daemon::ServeReply::Busy { .. } => {
            println!("daemon busy — request shed; retry later");
        }
        srr::serve::daemon::ServeReply::Error { message, .. } => {
            anyhow::bail!("daemon refused the request: {message}");
        }
    }
    Ok(())
}

fn cmd_bench(args: &Args) -> Result<()> {
    if args.has_flag("list") {
        for e in registry() {
            let tag = if e.offline_ok { " [offline-ok]" } else { "" };
            println!("{:10} {}{tag}", e.id, e.paper);
        }
        return Ok(());
    }
    let mut ctx = ExpCtx::new(args.has_flag("quick"))?;
    ctx.seed = args.get_u64("seed", 0);
    let ids: Vec<String> = if args.positional.is_empty() {
        registry().iter().map(|e| e.id.to_string()).collect()
    } else {
        args.positional.clone()
    };
    for id in ids {
        let t0 = std::time::Instant::now();
        match srr::exp::run(&id, &mut ctx) {
            Ok(tables) => {
                for t in tables {
                    t.print();
                }
                println!("[{id} done in {:.1}s]", t0.elapsed().as_secs_f64());
            }
            Err(e) => eprintln!("[{id} FAILED: {e:#}]"),
        }
    }
    Ok(())
}
