//! Cross-layer integration tests: the rust stack (L3) against the real
//! AOT artifacts (L2/L1) through PJRT.
//!
//! These are the tests that pin all three layers to the same semantics:
//! * the rust-native forward (calibration path) must match the JAX
//!   `lm_fwd`/`lm_nll` artifacts;
//! * the rust MXINT quantizer must match the Pallas kernel bit-for-bit;
//! * the fused QLR kernel must match the rust-side composition.
//!
//! They require `make artifacts` and a `--features pjrt` build; without
//! either, every test here skips cleanly with a stderr note so
//! `cargo test -q` passes on a fresh clone.

use srr::model::{forward, synth::synth_lm_params};
use srr::quant::{MxintQuantizer, QuantCtx, Quantizer};
use srr::runtime::{Engine, Executor, TensorValue};
use srr::tensor::Mat;
use srr::util::Rng;

mod common;

fn engine() -> Option<Engine> {
    common::engine("integration")
}

fn tokens_batch(vocab: usize, b: usize, t: usize, seed: u64) -> Vec<i32> {
    let mut rng = Rng::new(seed);
    (0..b * t).map(|_| rng.below(vocab) as i32).collect()
}

#[test]
fn lm_fwd_tiny_matches_rust_native_forward() {
    let Some(eng) = engine() else { return };
    let cfg = eng.manifest().model("tiny").unwrap().clone();
    let b = eng.manifest().lm_batch;
    let params = synth_lm_params(&cfg, 11, cfg.vocab);
    let toks = tokens_batch(cfg.vocab, b, cfg.seq_len, 12);

    let mut inputs = params.flat().unwrap();
    inputs.push(TensorValue::i32(vec![b, cfg.seq_len], toks.clone()));
    let outs = eng.run("lm_fwd_tiny", &inputs).unwrap();
    assert_eq!(outs.len(), 1);
    assert_eq!(outs[0].shape(), &[b, cfg.seq_len, cfg.vocab]);

    let native = forward::forward(&params, &cfg, &toks, b, cfg.seq_len, true, None);
    let pjrt = outs[0].as_f32();
    let mut max_diff = 0.0f32;
    for (i, (&a, &r)) in pjrt.iter().zip(&native.data).enumerate() {
        let d = (a - r).abs();
        if d > max_diff {
            max_diff = d;
        }
        assert!(d < 5e-2, "logit {i}: pjrt {a} vs native {r}");
    }
    assert!(max_diff < 5e-2, "max diff {max_diff}");
}

#[test]
fn lm_nll_tiny_matches_rust_native_nll() {
    let Some(eng) = engine() else { return };
    let cfg = eng.manifest().model("tiny").unwrap().clone();
    let b = eng.manifest().lm_batch;
    let params = synth_lm_params(&cfg, 21, cfg.vocab);
    let toks = tokens_batch(cfg.vocab, b, cfg.seq_len, 22);
    let mut mask = vec![1.0f32; b * cfg.seq_len];
    // exercise masking: zero the tail of sequence 3
    for v in mask[3 * cfg.seq_len + 40..4 * cfg.seq_len].iter_mut() {
        *v = 0.0;
    }

    let mut inputs = params.flat().unwrap();
    inputs.push(TensorValue::i32(vec![b, cfg.seq_len], toks.clone()));
    inputs.push(TensorValue::f32(vec![b, cfg.seq_len], mask.clone()));
    let outs = eng.run("lm_nll_tiny", &inputs).unwrap();
    assert_eq!(outs.len(), 2);

    let (nll_native, cnt_native) = forward::lm_nll(&params, &cfg, &toks, &mask, b, cfg.seq_len);
    let nll_pjrt = outs[0].as_f32();
    let cnt_pjrt = outs[1].as_f32();
    for i in 0..b {
        assert!(
            (nll_pjrt[i] as f64 - nll_native[i]).abs() < 0.05 * nll_native[i].max(1.0),
            "seq {i}: pjrt {} vs native {}",
            nll_pjrt[i],
            nll_native[i]
        );
        assert_eq!(cnt_pjrt[i] as f64, cnt_native[i], "count mismatch seq {i}");
    }
}

#[test]
fn mxint_kernel_artifact_matches_rust_quantizer() {
    let Some(eng) = engine() else { return };
    let mut rng = Rng::new(33);
    let w = Mat::randn(128, 256, 1.0, &mut rng);
    for bits in [2u32, 3, 4] {
        let outs = eng
            .run(&format!("kernel_mxint{bits}"), &[TensorValue::from_mat(&w)])
            .unwrap();
        let kernel = outs[0].to_mat();
        let native = MxintQuantizer::new(bits, 32).quantize(&w, &QuantCtx::default());
        assert!(
            kernel.allclose(&native, 0.0),
            "MXINT{bits}: Pallas kernel and rust quantizer disagree"
        );
    }
}

#[test]
fn qlr_kernel_artifact_matches_rust_composition() {
    let Some(eng) = engine() else { return };
    let mut rng = Rng::new(44);
    let x = Mat::randn(64, 256, 0.5, &mut rng);
    let q = Mat::randn(256, 256, 0.1, &mut rng);
    let l = Mat::randn(256, 64, 0.1, &mut rng);
    let r = Mat::randn(64, 256, 0.1, &mut rng);
    let outs = eng
        .run(
            "kernel_qlr",
            &[
                TensorValue::from_mat(&x),
                TensorValue::from_mat(&q),
                TensorValue::from_mat(&l),
                TensorValue::from_mat(&r),
            ],
        )
        .unwrap();
    let fused = outs[0].to_mat();
    use srr::tensor::matmul;
    let want = matmul(&x, &q).add(&matmul(&matmul(&x, &l), &r));
    assert!(fused.allclose(&want, 3e-3), "fused QLR kernel mismatch");
}

#[test]
fn attention_kernel_artifact_is_causal() {
    let Some(eng) = engine() else { return };
    let mut rng = Rng::new(55);
    let shape = vec![2usize, 4, 64, 32];
    let n: usize = shape.iter().product();
    let mut qkv = Vec::new();
    for _ in 0..3 {
        let mut d = vec![0.0f32; n];
        rng.fill_normal(&mut d, 1.0);
        qkv.push(TensorValue::f32(shape.clone(), d));
    }
    let out1 = eng.run("kernel_attn", &qkv).unwrap();
    // mutate the last key/value position; outputs at earlier query
    // positions must not change (causality through the whole kernel)
    let mut qkv2 = qkv.clone();
    if let TensorValue::F32 { data, .. } = &mut qkv2[1] {
        let stride = 64 * 32;
        for bh in 0..8 {
            for dk in 0..32 {
                data[bh * stride + 63 * 32 + dk] += 1.0;
            }
        }
    }
    let out2 = eng.run("kernel_attn", &qkv2).unwrap();
    let a = out1[0].as_f32();
    let b = out2[0].as_f32();
    let stride = 64 * 32;
    for bh in 0..8 {
        for pos in 0..63 {
            for dk in 0..32 {
                let idx = bh * stride + pos * 32 + dk;
                assert!(
                    (a[idx] - b[idx]).abs() < 1e-5,
                    "future key leaked into position {pos}"
                );
            }
        }
    }
    // ... but the last position must change
    let mut changed = false;
    for bh in 0..8 {
        for dk in 0..32 {
            let idx = bh * stride + 63 * 32 + dk;
            if (a[idx] - b[idx]).abs() > 1e-4 {
                changed = true;
            }
        }
    }
    assert!(changed, "last position should respond to its own key");
}

#[test]
fn engine_rejects_wrong_shapes_and_caches_compiles() {
    let Some(eng) = engine() else { return };
    let bad = vec![TensorValue::zeros(vec![2, 2])];
    assert!(eng.run("kernel_mxint3", &bad).is_err());
    assert!(eng.run("unknown_artifact", &bad).is_err());

    let mut rng = Rng::new(66);
    let w = Mat::randn(128, 256, 1.0, &mut rng);
    let before = eng.compiled_count();
    eng.run("kernel_mxint3", &[TensorValue::from_mat(&w)]).unwrap();
    let mid = eng.compiled_count();
    eng.run("kernel_mxint3", &[TensorValue::from_mat(&w)]).unwrap();
    assert_eq!(mid, eng.compiled_count(), "second call must hit the compile cache");
    assert!(mid > before);
}
