//! End-to-end pipeline integration: coordinator → eval → QPEFT over the
//! real PJRT artifacts. Requires `make artifacts` and a `--features
//! pjrt` build; without either, every test skips cleanly with a stderr
//! note so `cargo test -q` passes on a fresh clone.

use srr::coordinator::{run_ptq, Metrics, QuantizerSpec};
use srr::data::glue_sim::GlueTask;
use srr::data::Corpus;
use srr::eval::perplexity;
use srr::model::{collect_calibration, synth_lm_params};
use srr::qer::{Method, QerConfig};
use srr::qpeft::{init_qpeft, GradScale, QpeftInit, QpeftTrainer};
use srr::runtime::{Engine, Executor, TensorValue};
use srr::scaling::ScalingKind;
use srr::tensor::Mat;
use srr::util::Rng;

mod common;

fn engine() -> Option<Engine> {
    common::engine("pipeline")
}

#[test]
fn ptq_pipeline_to_ppl_end_to_end() {
    let Some(eng) = engine() else { return };
    let cfg = eng.manifest().model("tiny").unwrap().clone();
    let b = eng.manifest().lm_batch;
    let params = synth_lm_params(&cfg, 3, cfg.vocab);
    let corpus = Corpus::generate(cfg.vocab, 30_000, 4);
    let batches: Vec<Vec<i32>> = (0..4).map(|i| corpus.train_batch(b, cfg.seq_len, i)).collect();
    let calib = collect_calibration(&params, &cfg, &batches, b, cfg.seq_len, 256);

    let metrics = Metrics::new();
    let out = run_ptq(
        &params,
        &cfg,
        &calib,
        QuantizerSpec::Mxint { bits: 3, block: 32 },
        &QerConfig::new(Method::QerSrr, 8, ScalingKind::DiagRms),
        &metrics,
    );
    assert_eq!(out.reports.len(), 7 * cfg.n_layers);

    // reconstructed model must run through PJRT and produce a finite PPL
    let eval: Vec<Vec<i32>> = corpus.eval_batches(b, cfg.seq_len).into_iter().take(2).collect();
    let ppl_q = perplexity(&eng, "lm_nll_tiny", &out.params, &eval, b, cfg.seq_len).unwrap();
    let ppl_fp = perplexity(&eng, "lm_nll_tiny", &params, &eval, b, cfg.seq_len).unwrap();
    assert!(ppl_q.is_finite() && ppl_q > 1.0);
    assert!(ppl_fp.is_finite() && ppl_fp > 1.0);
    // 3-bit on an untrained model: reconstruction stays within a factor
    assert!(ppl_q < ppl_fp * 1.5, "ppl_q={ppl_q} vs fp={ppl_fp}");
    assert!(metrics.get("ptq.layers") as usize == out.reports.len());
}

#[test]
fn qpeft_training_reduces_loss_through_real_artifact() {
    let Some(eng) = engine() else { return };
    let cfg = eng.manifest().model("tiny").unwrap().clone();
    let m = eng.manifest();
    let (batch, seq, classes) = (m.cls_batch, m.cls_seq, m.cls_classes);
    let params = synth_lm_params(&cfg, 5, cfg.vocab);
    let corpus = Corpus::generate(cfg.vocab, 20_000, 6);
    let b = m.lm_batch;
    let batches: Vec<Vec<i32>> = (0..3).map(|i| corpus.train_batch(b, cfg.seq_len, i)).collect();
    let calib = collect_calibration(&params, &cfg, &batches, b, cfg.seq_len, 128);

    let tasks = GlueTask::all(cfg.vocab, seq, 128, 16, 11);
    let task = &tasks[3]; // SST-sim: strong pattern
    let mut rng = Rng::new(12);
    let head = Mat::randn(cfg.d_model, classes, 0.02, &mut rng);
    let state = init_qpeft(
        &params,
        &cfg,
        &calib,
        QuantizerSpec::Mxint { bits: 3, block: 32 },
        QpeftInit::Srr,
        8,
        head,
        0,
    );
    assert!(state.adapters.iter().any(|a| a.k_star > 0));
    let mut trainer = QpeftTrainer::new(
        &eng,
        "qpeft_cls_train_tiny_r8",
        state,
        1e-3,
        GradScale::Fixed { gamma: 0.1 },
    );
    let mut first = None;
    for step in 0..25 {
        let (toks, labels, _) = GlueTask::batch(&task.train, step * batch, batch, seq);
        let loss = trainer
            .step(&[
                TensorValue::i32(vec![batch, seq], toks),
                TensorValue::i32(vec![batch], labels),
            ])
            .unwrap();
        first.get_or_insert(loss);
    }
    let last = trainer.final_loss(5);
    assert!(
        last < first.unwrap(),
        "loss should drop: {} -> {last}",
        first.unwrap()
    );

    // eval artifact runs with the trained state
    let (toks, _, _) = GlueTask::batch(&task.dev, 0, batch, seq);
    let out = trainer
        .eval("qpeft_cls_fwd_tiny_r8", &[TensorValue::i32(vec![batch, seq], toks)])
        .unwrap();
    assert_eq!(out.shape(), &[batch, classes]);
}

#[test]
fn lm_train_artifact_step_descends() {
    // a short full-FT run through lm_train_tiny (the e2e driver's inner loop)
    let Some(eng) = engine() else { return };
    let cfg = eng.manifest().model("tiny").unwrap().clone();
    let b = eng.manifest().lm_batch;
    let params = synth_lm_params(&cfg, 7, cfg.vocab);
    let corpus = Corpus::generate(cfg.vocab, 20_000, 8);
    let mut p = params.clone();
    let (first, last) = srr::exp::fixtures::train_lm(
        &eng, &cfg, &mut p, &corpus, "lm_train_tiny", b, 12, 3e-3,
    )
    .unwrap();
    assert!(last < first, "training loss must decrease: {first} -> {last}");
}
