//! Shared helpers for the PJRT-bound integration test suites.

use srr::runtime::Engine;

/// `Some(engine)` when the PJRT artifacts are executable, `None` (after
/// a stderr note naming `suite`) otherwise — `cargo test -q` must pass
/// on a fresh clone with neither `artifacts/` nor the `pjrt` feature.
pub fn engine(suite: &str) -> Option<Engine> {
    if cfg!(not(feature = "pjrt")) {
        eprintln!("skipping PJRT {suite} test: built without the `pjrt` feature");
        return None;
    }
    match Engine::discover() {
        Ok(e) => Some(e),
        Err(e) => {
            eprintln!("skipping PJRT {suite} test: {e:#} (run `make artifacts`)");
            None
        }
    }
}
