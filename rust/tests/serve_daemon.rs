//! Live-daemon integration regressions for `serve::daemon`, offline
//! (no PJRT, no artifacts):
//!
//! * **Protocol negatives** — truncated, bit-corrupted, cross-version,
//!   wrong-kind, and oversized frames from a handshaken TCP peer (plus
//!   a peer that never handshakes at all) are refused without a panic
//!   and without wedging the accept loop: a well-behaved client dialing
//!   in afterwards is still served.
//! * **Churn soak** — clients that disconnect mid-stream or wedge
//!   mid-frame ([`FaultPlan`]) free their scheduler slots, admission
//!   beyond `max_slots` is shed with an explicit busy reply, and after
//!   the churn the full slot pool is provably usable again (no leak).
//!
//! Both suites serve two rank variants sharing one packed base — the
//! deployment shape the daemon exists for.

use std::io::Write;
use std::net::{Shutdown, SocketAddr, TcpStream};
use std::sync::Arc;
use std::time::{Duration, Instant};

use srr::coordinator::jobs::byte_pipe;
use srr::coordinator::transport::worker_connect;
use srr::coordinator::wire::{kind, Frame};
use srr::coordinator::{FaultPlan, FaultTransport, QuantizerSpec};
use srr::model::{synth_lm_params, Params};
use srr::quant::{QuantCtx, Quantizer};
use srr::runtime::manifest::ModelCfg;
use srr::serve::daemon::protocol::{encode_request, SERVE_MAX_REQUEST_LEN};
use srr::serve::daemon::{
    Daemon, DaemonConfig, DaemonHandle, FleetEngine, ReqKind, ServeClient, ServeReply,
    ServeRequest,
};
use srr::serve::{FactoredModel, LinearOp, QuantBase};
use srr::tensor::Mat;
use srr::util::Rng;

fn tiny_cfg() -> ModelCfg {
    ModelCfg {
        name: "tiny-serve".into(),
        vocab: 48,
        d_model: 32,
        n_heads: 2,
        n_layers: 1,
        d_ff: 64,
        seq_len: 16,
    }
}

/// Rank variants sharing one `Arc<PackedMat>` base per linear — the
/// multi-variant serving shape, shrunk to test size.
fn shared_base_variants(cfg: &ModelCfg, ranks: &[usize], seed: u64) -> Vec<(String, FactoredModel)> {
    let mut rng = Rng::new(seed);
    let params = synth_lm_params(cfg, seed, cfg.vocab);
    let spec = QuantizerSpec::Mxint { bits: 4, block: 32 };
    let names = Params::linear_names(cfg);
    let bases: Vec<(String, QuantBase)> = names
        .iter()
        .map(|n| {
            let w = params.get_mat(n).expect("linear");
            let ctx = QuantCtx { hessian: None, seed };
            let (_, packed) = spec.build().quantize_coded(&w, &ctx);
            (n.clone(), QuantBase::Packed(Arc::new(packed.expect("packable"))))
        })
        .collect();
    ranks
        .iter()
        .map(|&rank| {
            let mut skeleton = params.clone();
            let ops: Vec<(String, LinearOp)> = bases
                .iter()
                .map(|(n, base)| {
                    skeleton.unset(n);
                    let (m, k) = (base.rows(), base.cols());
                    let op = LinearOp::FactoredQlr {
                        base: base.clone(),
                        l: Mat::randn(m, rank, 0.05, &mut rng),
                        r: Mat::randn(rank, k, 0.05, &mut rng),
                    };
                    (n.clone(), op)
                })
                .collect();
            (format!("r{rank}"), FactoredModel { skeleton, ops })
        })
        .collect()
}

fn spawn_daemon(cfg: DaemonConfig, tcp: bool) -> (DaemonHandle, Option<SocketAddr>) {
    let mcfg = tiny_cfg();
    let engine = FleetEngine::new(mcfg.clone(), shared_base_variants(&mcfg, &[2, 4], 17))
        .expect("aligned variants");
    let mut daemon = Daemon::new(engine, cfg);
    let addr = if tcp { Some(daemon.bind("127.0.0.1:0").expect("bind loopback")) } else { None };
    (daemon.spawn(), addr)
}

/// Poll `cond` until it holds or the deadline expires (daemon stats are
/// updated by the event loop, not synchronously with client IO).
fn wait_for(what: &str, cond: impl Fn() -> bool) {
    let deadline = Instant::now() + Duration::from_secs(10);
    while !cond() {
        assert!(Instant::now() < deadline, "timed out waiting for {what}");
        std::thread::sleep(Duration::from_millis(5));
    }
}

/// Serialize one frame to bytes (so tests can corrupt them).
fn frame_bytes(frame: &Frame) -> Vec<u8> {
    let mut buf = Vec::new();
    frame.write_to(&mut buf).expect("vec write");
    buf
}

fn request_frame(id: u64) -> Frame {
    encode_request(&ServeRequest {
        id,
        variant: "r2".into(),
        tokens: vec![1, 2, 3],
        kind: ReqKind::Generate { max_new: 2 },
    })
}

/// A handshaken TCP connection that sends `bytes` and half-closes; the
/// daemon must end only this connection.
fn send_raw(addr: &SocketAddr, bytes: &[u8]) {
    let mut stream = worker_connect(&addr.to_string(), 0).expect("handshake");
    stream.write_all(bytes).expect("send raw bytes");
    let _ = stream.flush();
    let _ = stream.shutdown(Shutdown::Write);
}

#[test]
fn protocol_negatives_never_wedge_the_daemon() {
    let (handle, addr) = spawn_daemon(DaemonConfig::default(), true);
    let addr = addr.expect("tcp bound");

    // a peer that is not even the protocol: refused at the HELLO
    // handshake, never reaches the request plane
    {
        let mut stream = TcpStream::connect(addr).expect("connect");
        stream.write_all(b"GET / HTTP/1.1\r\n\r\n").expect("garbage");
        let _ = stream.shutdown(Shutdown::Write);
    }

    // truncated: a frame cut mid-header
    send_raw(&addr, &frame_bytes(&request_frame(1))[..10]);
    // corrupted: one payload bit flipped — the checksum must catch it
    let mut corrupt = frame_bytes(&request_frame(2));
    corrupt[16] ^= 0x40;
    send_raw(&addr, &corrupt);
    // cross-version: a frame stamped with a future wire version
    let mut future = frame_bytes(&request_frame(3));
    future[4..6].copy_from_slice(&2u16.to_le_bytes());
    send_raw(&addr, &future);
    // wrong role: a shard-plane frame kind on the serving port
    send_raw(&addr, &frame_bytes(&Frame { kind: kind::SWEEP_JOB, payload: vec![7] }));
    // oversized: a header advertising a payload over the request cap;
    // the daemon must refuse from the header alone, not allocate it
    let mut oversized = frame_bytes(&request_frame(4))[..16].to_vec();
    oversized[8..16].copy_from_slice(&(SERVE_MAX_REQUEST_LEN + 1).to_le_bytes());
    send_raw(&addr, &oversized);

    // all five post-handshake violations are counted and end only
    // their own connection
    wait_for("malformed connections to be dropped", || {
        handle.stats().malformed.load(std::sync::atomic::Ordering::Relaxed) >= 5
    });

    // a well-behaved client dialing in after the abuse is served
    let mut client = ServeClient::dial(&addr.to_string(), "r2").expect("dial");
    match client.generate(&[1, 2, 3], 2).expect("generate") {
        ServeReply::Tokens { id, tokens } => {
            assert_eq!(id, 1);
            assert_eq!(tokens.len(), 2);
        }
        other => panic!("expected tokens, got {other:?}"),
    }

    // an invalid but well-formed request is refused with a structured
    // error — and the connection survives to serve the next request
    match client.generate(&[1, 2, 999], 2).expect("refused generate") {
        ServeReply::Error { message, .. } => {
            assert!(message.contains("vocab"), "unexpected refusal: {message}");
        }
        other => panic!("expected error reply, got {other:?}"),
    }
    match client.score(&[4, 5, 6]).expect("score after refusal") {
        ServeReply::Score { count, .. } => assert_eq!(count, 2.0),
        other => panic!("expected score, got {other:?}"),
    }

    wait_for("slots to drain", || {
        handle.stats().active_slots.load(std::sync::atomic::Ordering::Relaxed) == 0
    });
    handle.join();
}

/// Attach an in-process client through a fault-injecting loopback
/// transport (the daemon side sees `plan`'s faults).
fn attach(handle: &DaemonHandle, plan: FaultPlan, variant: &str) -> ServeClient {
    let (client_w, daemon_r) = byte_pipe(1 << 16);
    let (daemon_w, client_r) = byte_pipe(1 << 16);
    let t = FaultTransport::new(daemon_w, daemon_r, plan);
    assert!(handle.admit(Box::new(t)), "daemon accepting connections");
    ServeClient::over(Box::new(client_w), Box::new(client_r), variant)
}

#[test]
fn churn_soak_frees_slots_and_sheds_with_busy() {
    let cfg = DaemonConfig { max_slots: 2, max_batch: 2, ..DaemonConfig::default() };
    let (handle, _) = spawn_daemon(cfg, false);
    let stats = || handle.stats();
    let load = |a: &std::sync::atomic::AtomicUsize| a.load(std::sync::atomic::Ordering::Relaxed);
    let load64 = |a: &std::sync::atomic::AtomicU64| a.load(std::sync::atomic::Ordering::Relaxed);

    // --- admission sheds beyond max_slots with an explicit busy reply.
    // Four long generate requests back-to-back on one connection: the
    // event loop admits two, and the rest arrive while both slots are
    // held mid-decode.
    let mut a = attach(&handle, FaultPlan::default(), "r2");
    for _ in 0..4 {
        a.send_generate(&[1, 2], 14).expect("send");
    }
    let mut busy = 0;
    let mut tokens = 0;
    for _ in 0..4 {
        match a.recv().expect("reply") {
            ServeReply::Busy { .. } => busy += 1,
            ServeReply::Tokens { tokens: t, .. } => {
                assert_eq!(t.len(), 14);
                tokens += 1;
            }
            other => panic!("unexpected reply {other:?}"),
        }
    }
    assert!(busy >= 1, "no request was shed at capacity");
    assert_eq!(busy + tokens, 4);
    assert!(load64(&stats().shed) >= 1);
    drop(a);

    // --- mid-stream disconnect frees the slots it held
    let mut b = attach(&handle, FaultPlan::default(), "r4");
    b.send_generate(&[3, 4], 14).expect("send");
    drop(b); // both pipe ends close: EOF mid-decode
    wait_for("disconnect to free slots", || {
        load(&stats().active_slots) == 0 && load64(&stats().disconnects) >= 2
    });

    // --- a connection wedged mid-frame (stall: no bytes, no EOF) must
    // not block service to anyone else
    let mut c = attach(
        &handle,
        FaultPlan { stall_rx_after: Some(8), stall_rx_resume: None, ..FaultPlan::default() },
        "r2",
    );
    c.send_generate(&[5, 6], 2).expect("send into stall");
    // ...and a byte-chopping link still serves correctly
    let mut d = attach(&handle, FaultPlan { chop: 3, ..FaultPlan::default() }, "r4");
    match d.generate(&[7, 8, 9], 3).expect("generate over chopped link") {
        ServeReply::Tokens { tokens, .. } => assert_eq!(tokens.len(), 3),
        other => panic!("expected tokens, got {other:?}"),
    }

    // --- no slot leak: after the churn the full pool is usable again
    wait_for("churned slots to drain", || load(&stats().active_slots) == 0);
    let mut e = attach(&handle, FaultPlan::default(), "r2");
    let id1 = e.send_generate(&[1, 2, 3], 4).expect("send");
    let id2 = e.send_score(&[4, 5, 6, 7]).expect("send");
    let mut seen = 0;
    for _ in 0..2 {
        match e.recv().expect("reply") {
            ServeReply::Tokens { id, tokens } => {
                assert_eq!(id, id1);
                assert_eq!(tokens.len(), 4);
                seen += 1;
            }
            ServeReply::Score { id, count, .. } => {
                assert_eq!(id, id2);
                assert_eq!(count, 3.0);
                seen += 1;
            }
            other => panic!("unexpected reply {other:?}"),
        }
    }
    assert_eq!(seen, 2, "full slot pool served after churn");

    assert!(load64(&stats().served) >= 4);
    handle.join();
    // the wedged client's transport was severed at shutdown; its
    // parked reader saw EOF rather than wedging the daemon's teardown
    drop(c);
}
