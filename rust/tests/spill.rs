//! Out-of-core sweep regression: `run_sweep_spilled` through a
//! `SpillStore` must be bit-identical to the in-memory
//! `SweepRunner::run_factored` — outcomes, `Arc` lock-step grouping,
//! and fleet PPL — including after the run is killed mid-sweep (at a
//! chunk boundary or mid-append with a torn manifest record) and
//! resumed from the spill dir, in-process and across real process
//! boundaries (`srr ptq --spill`).
//!
//! Runs offline (no PJRT, no artifacts). The CLI binary is
//! `CARGO_BIN_EXE_srr`; the kill points are injected with
//! `SRR_SPILL_KILL_AFTER` / `SRR_SPILL_KILL_TORN` (exit 17).

use std::path::PathBuf;
use std::process::Command;
use std::sync::atomic::{AtomicU64, Ordering};

use srr::coordinator::spill::KILL_EXIT_CODE;
use srr::coordinator::{
    outcome_content_hash, run_sweep_spilled, FactoredOutcome, LayerAssign, Metrics,
    QuantizerSpec, ShardOptions, ShardSession, ShardedSweepRunner, SpillOptions, SpillStore,
    SweepConfig, SweepRunner,
};
use srr::data::Corpus;
use srr::eval::{fleet_perplexity, group_by_shared_bases};
use srr::model::{collect_calibration, synth_lm_params, CalibrationSet, Params};
use srr::qer::Method;
use srr::runtime::manifest::ModelCfg;
use srr::scaling::ScalingKind;
use srr::serve::FactoredModel;
use srr::util::prop;

/// Self-cleaning unique temp dir (spill dirs must not leak between or
/// after test runs).
struct TempDir(PathBuf);

impl TempDir {
    fn new(tag: &str) -> TempDir {
        static NEXT: AtomicU64 = AtomicU64::new(0);
        let dir = std::env::temp_dir().join(format!(
            "srr-spill-it-{tag}-{}-{}",
            std::process::id(),
            NEXT.fetch_add(1, Ordering::Relaxed)
        ));
        std::fs::create_dir_all(&dir).expect("create temp dir");
        TempDir(dir)
    }
}

impl Drop for TempDir {
    fn drop(&mut self) {
        let _ = std::fs::remove_dir_all(&self.0);
    }
}

fn setup() -> (Params, ModelCfg, CalibrationSet, Vec<Vec<i32>>) {
    let cfg = ModelCfg {
        name: "t".into(),
        vocab: 64,
        d_model: 64,
        n_heads: 2,
        n_layers: 2,
        d_ff: 128,
        seq_len: 16,
    };
    let params = synth_lm_params(&cfg, 5, cfg.vocab);
    let corpus = Corpus::generate(cfg.vocab, 4000, 6);
    let batches: Vec<Vec<i32>> = (0..10).map(|i| corpus.train_batch(2, 16, i)).collect();
    let calib = collect_calibration(&params, &cfg, &batches, 2, 16, 192);
    let eval_batches: Vec<Vec<i32>> =
        (0..3).map(|i| corpus.train_batch(2, cfg.seq_len, 40 + i)).collect();
    (params, cfg, calib, eval_batches)
}

/// A generated grid: a shared-base lock-step pair (w-only + QER on one
/// quantization), a generated (family, rank ∈ {0, 16, 64}, scaling)
/// SRR cell, and a heterogeneous per-layer cell — the mixed-group shape
/// the fleet evaluator has to keep grouping correctly after the disk
/// round-trip.
fn gen_grid(g: &mut prop::Gen, cfg: &ModelCfg) -> Vec<SweepConfig> {
    let mx = QuantizerSpec::Mxint { bits: 3, block: 32 };
    let families = [
        QuantizerSpec::Mxint { bits: 4, block: 32 },
        QuantizerSpec::Uniform { bits: 4, group: 32, symmetric: true },
        QuantizerSpec::Gptq { bits: 3, group: 64 },
    ];
    let fam = g.choice(&families);
    let rank = g.choice(&[0usize, 16, 64]);
    let scaling =
        g.choice(&[ScalingKind::Identity, ScalingKind::DiagRms, ScalingKind::DiagAbsMean]);
    let seed = g.dim(3) as u64;
    // heterogeneous cell: alternate quantizer and rank per linear
    let hetero: Vec<LayerAssign> = (0..Params::linear_names(cfg).len())
        .map(|li| LayerAssign {
            quantizer: if li % 2 == 0 { fam } else { mx },
            rank: if li % 2 == 0 { 4 } else { 8 },
        })
        .collect();
    vec![
        SweepConfig::new(mx, Method::WOnly, 0, ScalingKind::Identity).seeded(seed),
        SweepConfig::new(mx, Method::Qer, 8, ScalingKind::DiagRms).seeded(seed),
        SweepConfig::new(fam, Method::QerSrr, rank, scaling).seeded(seed),
        SweepConfig::new(mx, Method::QerSrr, 8, ScalingKind::DiagRms).with_per_layer(hetero),
    ]
}

fn assert_bit_identical(
    tag: &str,
    cfg: &ModelCfg,
    eval_batches: &[Vec<i32>],
    expect: &[FactoredOutcome],
    got: &[FactoredOutcome],
) {
    assert_eq!(expect.len(), got.len(), "{tag}: outcome count");
    for (ci, (a, b)) in expect.iter().zip(got).enumerate() {
        assert_eq!(
            outcome_content_hash(a),
            outcome_content_hash(b),
            "{tag} cfg {ci}: outcome content differs"
        );
    }
    let exp_models: Vec<&FactoredModel> = expect.iter().map(|o| &o.model).collect();
    let got_models: Vec<&FactoredModel> = got.iter().map(|o| &o.model).collect();
    assert_eq!(
        group_by_shared_bases(&exp_models),
        group_by_shared_bases(&got_models),
        "{tag}: lock-step grouping changed across the disk round-trip"
    );
    let exp_ppl = fleet_perplexity(&exp_models, cfg, eval_batches, 2, cfg.seq_len).expect("fleet");
    let got_ppl = fleet_perplexity(&got_models, cfg, eval_batches, 2, cfg.seq_len).expect("fleet");
    for (i, (a, b)) in exp_ppl.iter().zip(&got_ppl).enumerate() {
        assert_eq!(a.to_bits(), b.to_bits(), "{tag} model {i}: ppl {a} vs {b}");
    }
}

/// Property (replayable via `srr::util::prop::replay`): for generated
/// grids across quantizer families, ranks {0, 16, 64}, and mixed
/// lock-step groups, a spilled sweep under a tiny working-set cap —
/// every blob evicted and reloaded — is bit-identical to the in-memory
/// engine.
#[test]
fn spilled_sweep_bit_identical_to_in_memory() {
    let (params, cfg, calib, eval_batches) = setup();
    prop::check(0xD15C_0CAF, 3, |g| {
        let configs = gen_grid(g, &cfg);
        let metrics = Metrics::new();
        let expect = SweepRunner::new(&params, &cfg, &calib, &metrics).run_factored(&configs);
        let tmp = TempDir::new("prop");
        // 64 KiB cap: far below one layer's artifacts, so phase B2 and
        // assembly continuously evict and reload through the cache
        let opts = SpillOptions { cap_bytes: 64 << 10, ..Default::default() };
        let store = SpillStore::open(&tmp.0, opts).expect("open store");
        let got = run_sweep_spilled(&params, &cfg, &calib, &configs, &metrics, &store)
            .expect("spilled sweep");
        let stats = store.stats();
        assert!(stats.bytes_spilled > 0, "nothing was spilled");
        assert!(stats.bytes_reloaded > 0, "nothing streamed back through the cache");
        assert_bit_identical(
            &format!("case {:#x}", g.case_seed),
            &cfg,
            &eval_batches,
            &expect,
            &got,
        );
    });
}

/// Property: killing the run after a seeded number of durable manifest
/// appends — and, every other case, tearing the append itself mid-write
/// — then resuming from the same dir yields bit-identical outcomes. The
/// resumed run must also do strictly less work than a fresh one (the
/// completed chunks replay from the manifest).
#[test]
fn spilled_sweep_resumes_bit_identically_after_kill() {
    let (params, cfg, calib, eval_batches) = setup();
    prop::check(0x5EED_DEAD, 3, |g| {
        let configs = gen_grid(g, &cfg);
        let metrics = Metrics::new();
        let expect = SweepRunner::new(&params, &cfg, &calib, &metrics).run_factored(&configs);

        // a full run writes 1 header + one prep per linear + the shared
        // residual SVDs + one cell per (config, linear); kill anywhere
        // from the header append onwards
        let kill_at = g.dim(10); // dim is 1-based: 1 = the header append
        let torn = g.dim(2) == 1;
        let tmp = TempDir::new("resume");
        let opts = SpillOptions {
            cap_bytes: 64 << 10,
            abort_after_records: if torn { None } else { Some(kill_at) },
            torn_after_records: if torn { Some(kill_at) } else { None },
        };
        let store = SpillStore::open(&tmp.0, opts).expect("open store");
        let first = run_sweep_spilled(&params, &cfg, &calib, &configs, &metrics, &store);
        assert!(
            first.is_err(),
            "case {:#x}: the injected kill at record {kill_at} (torn: {torn}) must abort",
            g.case_seed
        );
        drop(store);

        let opts = SpillOptions { cap_bytes: 64 << 10, ..Default::default() };
        let store = SpillStore::open(&tmp.0, opts).expect("reopen store");
        let before = store.stats().records;
        if !torn {
            assert!(before >= kill_at, "durable records lost across the kill");
        }
        let got = run_sweep_spilled(&params, &cfg, &calib, &configs, &metrics, &store)
            .expect("resumed sweep");
        assert_bit_identical(
            &format!("case {:#x} kill_at {kill_at} torn {torn}", g.case_seed),
            &cfg,
            &eval_batches,
            &expect,
            &got,
        );
    });
}

/// The shard host drives the same spill store: phase B2 runs on real
/// spawned workers, cells spill as their results arrive over the wire,
/// and a second (single-worker) pass over the completed store replays
/// everything from the manifest — both bit-identical to the in-memory
/// engine.
#[test]
fn sharded_spilled_sweep_bit_identical_and_replayable() {
    let (params, cfg, calib, eval_batches) = setup();
    let mut g = prop::Gen { rng: srr::util::Rng::new(7), case_seed: 7 };
    let configs = gen_grid(&mut g, &cfg);
    let metrics = Metrics::new();
    let expect = SweepRunner::new(&params, &cfg, &calib, &metrics).run_factored(&configs);

    let tmp = TempDir::new("sharded");
    let opts = SpillOptions { cap_bytes: 64 << 10, ..Default::default() };
    let store = SpillStore::open(&tmp.0, opts).expect("open store");
    let shard_opts = ShardOptions {
        workers: 2,
        binary: Some(PathBuf::from(env!("CARGO_BIN_EXE_srr"))),
        ..Default::default()
    };
    let mut session = ShardSession::spawn(&shard_opts).expect("spawn workers");
    let runner = ShardedSweepRunner::new(&params, &cfg, &calib, &metrics);
    let got = runner.run_factored_spilled(&mut session, &configs, &store).expect("sharded spilled");
    session.shutdown();
    assert_bit_identical("sharded", &cfg, &eval_batches, &expect, &got);

    let shard_opts = ShardOptions {
        workers: 1,
        binary: Some(PathBuf::from(env!("CARGO_BIN_EXE_srr"))),
        ..Default::default()
    };
    let mut session = ShardSession::spawn(&shard_opts).expect("spawn worker");
    let replay =
        runner.run_factored_spilled(&mut session, &configs, &store).expect("manifest replay");
    session.shutdown();
    assert_bit_identical("sharded replay", &cfg, &eval_batches, &expect, &replay);
}

fn srr_ptq(spill_dir: &std::path::Path, kill: Option<(&str, usize)>) -> std::process::Output {
    let mut cmd = Command::new(env!("CARGO_BIN_EXE_srr"));
    cmd.args([
        "ptq", "--model", "tiny", "--method", "qer", "--quantizer", "mxint3", "--rank", "4",
        "--seed", "3", "--quick", "--spill",
    ]);
    cmd.arg(spill_dir);
    cmd.env_remove("SRR_SPILL_KILL_AFTER").env_remove("SRR_SPILL_KILL_TORN");
    if let Some((var, n)) = kill {
        cmd.env(var, n.to_string());
    }
    cmd.output().expect("run srr ptq")
}

fn hash_line(out: &std::process::Output) -> String {
    let stdout = String::from_utf8_lossy(&out.stdout);
    stdout
        .lines()
        .find(|l| l.starts_with("spill outcome hash = "))
        .unwrap_or_else(|| panic!("no spill outcome hash in stdout:\n{stdout}"))
        .to_string()
}

/// Tentpole acceptance, across real process boundaries: `srr ptq
/// --spill DIR` killed mid-sweep — once at a chunk boundary, once
/// mid-append (torn manifest record) — resumes from DIR and prints the
/// same outcome hash as an uninterrupted run in a fresh dir.
#[test]
fn cli_kill_and_resume_bit_identical() {
    let clean_dir = TempDir::new("cli-clean");
    let clean = srr_ptq(&clean_dir.0, None);
    assert!(clean.status.success(), "clean run failed: {clean:?}");
    let want = hash_line(&clean);

    let dir = TempDir::new("cli-killed");
    // kill 1: process exits right after the 3rd fsynced append (a chunk
    // boundary — the record is durable, the process is gone)
    let killed = srr_ptq(&dir.0, Some(("SRR_SPILL_KILL_AFTER", 3)));
    assert_eq!(
        killed.status.code(),
        Some(KILL_EXIT_CODE),
        "expected the injected kill, got: {killed:?}"
    );
    // kill 2: the resumed process dies *mid-append*, leaving a torn
    // trailing record for the next resume to truncate away
    let torn = srr_ptq(&dir.0, Some(("SRR_SPILL_KILL_TORN", 2)));
    assert_eq!(
        torn.status.code(),
        Some(KILL_EXIT_CODE),
        "expected the injected torn-write kill, got: {torn:?}"
    );
    // final resume completes the sweep from what survived both kills
    let resumed = srr_ptq(&dir.0, None);
    assert!(resumed.status.success(), "resumed run failed: {resumed:?}");
    assert_eq!(hash_line(&resumed), want, "resumed outcome diverged from the clean run");

    // re-running a *completed* spill dir replays everything from the
    // manifest and still reports the same outcome
    let replayed = srr_ptq(&dir.0, None);
    assert!(replayed.status.success(), "replay run failed: {replayed:?}");
    assert_eq!(hash_line(&replayed), want, "replayed outcome diverged");
}

/// A spill dir pinned to one sweep rejects a different one instead of
/// mixing artifacts: resuming with a different seed errors out.
#[test]
fn cli_rejects_mismatched_spill_dir() {
    let dir = TempDir::new("cli-mismatch");
    let first = srr_ptq(&dir.0, None);
    assert!(first.status.success(), "first run failed: {first:?}");

    let mut cmd = Command::new(env!("CARGO_BIN_EXE_srr"));
    cmd.args([
        "ptq", "--model", "tiny", "--method", "qer", "--quantizer", "mxint3", "--rank", "4",
        "--seed", "99", "--quick", "--spill",
    ]);
    cmd.arg(&dir.0);
    cmd.env_remove("SRR_SPILL_KILL_AFTER").env_remove("SRR_SPILL_KILL_TORN");
    let out = cmd.output().expect("run srr ptq");
    assert!(!out.status.success(), "a different sweep must not reuse the dir");
    let stderr = String::from_utf8_lossy(&out.stderr);
    assert!(stderr.contains("different sweep"), "unexpected stderr:\n{stderr}");
}
