//! Multi-process shard plane regression: `ShardedSweepRunner` +
//! `fleet_perplexity_sharded` against real spawned `srr shard-worker`
//! processes must be bit-identical to the in-process
//! `SweepRunner::run_factored` + `fleet_perplexity` for N ∈ {1, 2, 4}
//! workers — including after a worker dies mid-run and its jobs requeue.
//!
//! Runs offline (no PJRT, no artifacts). The worker binary is resolved
//! through `SRR_SHARD_BIN`, which cargo provides to integration tests as
//! `CARGO_BIN_EXE_srr`.

use srr::coordinator::{
    fleet_perplexity_sharded, FactoredOutcome, Metrics, QuantizerSpec, ShardOptions,
    ShardSession, ShardedSweepRunner, SweepConfig, SweepRunner,
};
use srr::data::Corpus;
use srr::eval::{fleet_perplexity, group_by_shared_bases};
use srr::model::{collect_calibration, synth_lm_params, CalibrationSet, Params};
use srr::qer::Method;
use srr::runtime::manifest::ModelCfg;
use srr::scaling::ScalingKind;
use srr::serve::{FactoredModel, LinearOp, QuantBase};

/// Point worker spawning at the binary cargo built for this test run.
fn shard_opts(workers: usize) -> ShardOptions {
    ShardOptions {
        workers,
        binary: Some(std::path::PathBuf::from(env!("CARGO_BIN_EXE_srr"))),
        ..Default::default()
    }
}

fn setup() -> (Params, ModelCfg, CalibrationSet, Vec<Vec<i32>>) {
    let cfg = ModelCfg {
        name: "t".into(),
        vocab: 64,
        d_model: 64,
        n_heads: 2,
        n_layers: 2,
        d_ff: 128,
        seq_len: 16,
    };
    let params = synth_lm_params(&cfg, 5, cfg.vocab);
    let corpus = Corpus::generate(cfg.vocab, 4000, 6);
    let batches: Vec<Vec<i32>> = (0..10).map(|i| corpus.train_batch(2, 16, i)).collect();
    let calib = collect_calibration(&params, &cfg, &batches, 2, 16, 192);
    let eval_batches: Vec<Vec<i32>> =
        (0..3).map(|i| corpus.train_batch(2, cfg.seq_len, 40 + i)).collect();
    (params, cfg, calib, eval_batches)
}

/// The regression grid: a shared-base cell (w-only + QER ranks over one
/// mxint quantization — a lock-step fleet group), the SRR family with
/// its own per-config quantization, and a GPTQ Hessian path.
fn grid() -> Vec<SweepConfig> {
    let mx = QuantizerSpec::Mxint { bits: 3, block: 32 };
    vec![
        SweepConfig::new(mx, Method::WOnly, 0, ScalingKind::Identity),
        SweepConfig::new(mx, Method::Qer, 4, ScalingKind::DiagRms),
        SweepConfig::new(mx, Method::Qer, 8, ScalingKind::DiagRms),
        SweepConfig::new(mx, Method::QerSrr, 8, ScalingKind::Exact).seeded(5),
        SweepConfig::new(
            QuantizerSpec::Gptq { bits: 3, group: 64 },
            Method::QerSrr,
            8,
            ScalingKind::DiagAbsMean,
        ),
    ]
}

fn assert_outcomes_identical(tag: &str, a: &[FactoredOutcome], b: &[FactoredOutcome]) {
    assert_eq!(a.len(), b.len(), "{tag}: outcome count");
    for (ci, (oa, ob)) in a.iter().zip(b).enumerate() {
        assert_eq!(oa.model.ops.len(), ob.model.ops.len(), "{tag} cfg {ci}: op count");
        for ((na, opa), (nb, opb)) in oa.model.ops.iter().zip(&ob.model.ops) {
            assert_eq!(na, nb, "{tag} cfg {ci}: op order");
            match (opa, opb) {
                (
                    LinearOp::FactoredQlr { base: ba, l: la, r: ra },
                    LinearOp::FactoredQlr { base: bb, l: lb, r: rb },
                ) => {
                    assert_eq!(la, lb, "{tag} cfg {ci} {na}: L differs");
                    assert_eq!(ra, rb, "{tag} cfg {ci} {na}: R differs");
                    assert_eq!(ba.densify(), bb.densify(), "{tag} cfg {ci} {na}: base differs");
                    assert_eq!(
                        matches!(ba, QuantBase::Packed(_)),
                        matches!(bb, QuantBase::Packed(_)),
                        "{tag} cfg {ci} {na}: packedness differs"
                    );
                }
                _ => panic!("{tag} cfg {ci} {na}: unexpected op shape"),
            }
        }
        for (ma, mb) in oa.meta.iter().zip(&ob.meta) {
            assert_eq!(ma.k_star, mb.k_star, "{tag} cfg {ci}: k* differs");
        }
        for (ra, rb) in oa.reports.iter().zip(&ob.reports) {
            assert_eq!(
                ra.weight_err.to_bits(),
                rb.weight_err.to_bits(),
                "{tag} cfg {ci} {}: weight_err differs",
                ra.name
            );
            assert_eq!(
                ra.scaled_err.to_bits(),
                rb.scaled_err.to_bits(),
                "{tag} cfg {ci} {}: scaled_err differs",
                ra.name
            );
        }
    }
}

/// Tentpole acceptance: sweep outcomes and fleet PPLs through N ∈
/// {1, 2, 4} worker processes are bit-identical to the in-process path,
/// and the wire preserves the lock-step grouping (shared packed bases).
#[test]
fn sharded_sweep_and_fleet_bit_identical_n_1_2_4() {
    let (params, cfg, calib, eval_batches) = setup();
    let configs = grid();
    let metrics = Metrics::new();
    let expect = SweepRunner::new(&params, &cfg, &calib, &metrics).run_factored(&configs);
    let exp_models: Vec<&FactoredModel> = expect.iter().map(|o| &o.model).collect();
    let exp_ppl = fleet_perplexity(&exp_models, &cfg, &eval_batches, 2, cfg.seq_len);

    for n in [1usize, 2, 4] {
        let mut session = ShardSession::spawn(&shard_opts(n)).expect("spawn workers");
        let runner = ShardedSweepRunner::new(&params, &cfg, &calib, &metrics);
        let outs = runner.run_factored(&mut session, &configs).expect("sharded sweep");
        assert_outcomes_identical(&format!("N={n}"), &expect, &outs);

        let models: Vec<&FactoredModel> = outs.iter().map(|o| &o.model).collect();
        // grid dedup / lock-step groups survive the wire round-trip
        assert_eq!(
            group_by_shared_bases(&exp_models),
            group_by_shared_bases(&models),
            "N={n}: lock-step grouping changed"
        );
        let ppl = fleet_perplexity_sharded(
            &mut session,
            &models,
            &cfg,
            &eval_batches,
            2,
            cfg.seq_len,
            &metrics,
        )
        .expect("sharded fleet");
        for (i, (a, b)) in exp_ppl.iter().zip(&ppl).enumerate() {
            assert_eq!(a.to_bits(), b.to_bits(), "N={n} model {i}: ppl {a} vs {b}");
        }
        session.shutdown();
    }
}

/// Worker-death requeue: the first of two workers exits after 2 jobs
/// (an abrupt EOF from the host's perspective); its in-flight jobs move
/// to the survivor and the merged result is still bit-identical.
#[test]
fn worker_death_requeues_bit_identically() {
    let (params, cfg, calib, eval_batches) = setup();
    let configs = grid();
    let metrics = Metrics::new();
    let expect = SweepRunner::new(&params, &cfg, &calib, &metrics).run_factored(&configs);

    let opts = ShardOptions { exit_after_first: Some(2), ..shard_opts(2) };
    let mut session = ShardSession::spawn(&opts).expect("spawn workers");
    let runner = ShardedSweepRunner::new(&params, &cfg, &calib, &metrics);
    let outs = runner.run_factored(&mut session, &configs).expect("sharded sweep with a death");
    assert_outcomes_identical("death", &expect, &outs);
    assert_eq!(session.n_alive(), 1, "worker 0 must have died");
    assert!(
        metrics.get("shard.worker_deaths") >= 1.0,
        "death not recorded: {}",
        metrics.get("shard.worker_deaths")
    );

    // the surviving worker also carries the fleet batch afterwards
    let models: Vec<&FactoredModel> = outs.iter().map(|o| &o.model).collect();
    let exp_models: Vec<&FactoredModel> = expect.iter().map(|o| &o.model).collect();
    let exp_ppl = fleet_perplexity(&exp_models, &cfg, &eval_batches, 2, cfg.seq_len);
    let ppl = fleet_perplexity_sharded(
        &mut session,
        &models,
        &cfg,
        &eval_batches,
        2,
        cfg.seq_len,
        &metrics,
    )
    .expect("fleet on survivor");
    for (a, b) in exp_ppl.iter().zip(&ppl) {
        assert_eq!(a.to_bits(), b.to_bits());
    }
    session.shutdown();
}

/// When every worker dies before finishing, the host errors out instead
/// of hanging (the pop_timeout liveness probe catches even a worker that
/// exits without a clean EOF handshake).
#[test]
fn all_workers_dead_is_an_error_not_a_hang() {
    let (params, cfg, calib, _) = setup();
    let configs = grid();
    let metrics = Metrics::new();
    let opts = ShardOptions { exit_after_first: Some(1), ..shard_opts(1) };
    let mut session = ShardSession::spawn(&opts).expect("spawn worker");
    let runner = ShardedSweepRunner::new(&params, &cfg, &calib, &metrics);
    let err = runner
        .run_factored(&mut session, &configs)
        .expect_err("single worker dying after one job must fail the run");
    assert!(
        err.to_string().contains("all shard workers died"),
        "unexpected error: {err:#}"
    );
}

/// An empty grid never spawns work and mirrors the in-process shape.
#[test]
fn empty_grid_is_a_noop_without_worker_traffic() {
    let (params, cfg, calib, _) = setup();
    let metrics = Metrics::new();
    let mut session = ShardSession::spawn(&shard_opts(1)).expect("spawn worker");
    let runner = ShardedSweepRunner::new(&params, &cfg, &calib, &metrics);
    let outs = runner.run_factored(&mut session, &[]).expect("empty grid");
    assert!(outs.is_empty());
    session.shutdown();
}
