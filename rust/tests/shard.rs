//! Multi-process shard plane regression: `ShardedSweepRunner` +
//! `fleet_perplexity_sharded` against real spawned `srr shard-worker`
//! processes must be bit-identical to the in-process
//! `SweepRunner::run_factored` + `fleet_perplexity` for N ∈ {1, 2, 4}
//! workers — including after a worker dies mid-run and its jobs requeue,
//! and after a fresh worker dials in mid-run and is admitted on the fly.
//!
//! Runs offline (no PJRT, no artifacts). The worker binary is resolved
//! through `SRR_SHARD_BIN`, which cargo provides to integration tests as
//! `CARGO_BIN_EXE_srr`.

use srr::coordinator::{
    fleet_perplexity_sharded, FactoredOutcome, Metrics, QuantizerSpec, ShardOptions,
    ShardSession, ShardedSweepRunner, SweepConfig, SweepRunner,
};
use srr::data::Corpus;
use srr::eval::{fleet_perplexity, group_by_shared_bases};
use srr::model::{collect_calibration, synth_lm_params, CalibrationSet, Params};
use srr::qer::Method;
use srr::runtime::manifest::ModelCfg;
use srr::scaling::ScalingKind;
use srr::serve::{FactoredModel, LinearOp, QuantBase};

/// Point worker spawning at the binary cargo built for this test run.
fn shard_opts(workers: usize) -> ShardOptions {
    ShardOptions {
        workers,
        binary: Some(std::path::PathBuf::from(env!("CARGO_BIN_EXE_srr"))),
        ..Default::default()
    }
}

fn setup() -> (Params, ModelCfg, CalibrationSet, Vec<Vec<i32>>) {
    let cfg = ModelCfg {
        name: "t".into(),
        vocab: 64,
        d_model: 64,
        n_heads: 2,
        n_layers: 2,
        d_ff: 128,
        seq_len: 16,
    };
    let params = synth_lm_params(&cfg, 5, cfg.vocab);
    let corpus = Corpus::generate(cfg.vocab, 4000, 6);
    let batches: Vec<Vec<i32>> = (0..10).map(|i| corpus.train_batch(2, 16, i)).collect();
    let calib = collect_calibration(&params, &cfg, &batches, 2, 16, 192);
    let eval_batches: Vec<Vec<i32>> =
        (0..3).map(|i| corpus.train_batch(2, cfg.seq_len, 40 + i)).collect();
    (params, cfg, calib, eval_batches)
}

/// The regression grid: a shared-base cell (w-only + QER ranks over one
/// mxint quantization — a lock-step fleet group), the SRR family with
/// its own per-config quantization, and a GPTQ Hessian path.
fn grid() -> Vec<SweepConfig> {
    let mx = QuantizerSpec::Mxint { bits: 3, block: 32 };
    vec![
        SweepConfig::new(mx, Method::WOnly, 0, ScalingKind::Identity),
        SweepConfig::new(mx, Method::Qer, 4, ScalingKind::DiagRms),
        SweepConfig::new(mx, Method::Qer, 8, ScalingKind::DiagRms),
        SweepConfig::new(mx, Method::QerSrr, 8, ScalingKind::Exact).seeded(5),
        SweepConfig::new(
            QuantizerSpec::Gptq { bits: 3, group: 64 },
            Method::QerSrr,
            8,
            ScalingKind::DiagAbsMean,
        ),
    ]
}

fn assert_outcomes_identical(tag: &str, a: &[FactoredOutcome], b: &[FactoredOutcome]) {
    assert_eq!(a.len(), b.len(), "{tag}: outcome count");
    for (ci, (oa, ob)) in a.iter().zip(b).enumerate() {
        assert_eq!(oa.model.ops.len(), ob.model.ops.len(), "{tag} cfg {ci}: op count");
        for ((na, opa), (nb, opb)) in oa.model.ops.iter().zip(&ob.model.ops) {
            assert_eq!(na, nb, "{tag} cfg {ci}: op order");
            match (opa, opb) {
                (
                    LinearOp::FactoredQlr { base: ba, l: la, r: ra },
                    LinearOp::FactoredQlr { base: bb, l: lb, r: rb },
                ) => {
                    assert_eq!(la, lb, "{tag} cfg {ci} {na}: L differs");
                    assert_eq!(ra, rb, "{tag} cfg {ci} {na}: R differs");
                    assert_eq!(ba.densify(), bb.densify(), "{tag} cfg {ci} {na}: base differs");
                    assert_eq!(
                        matches!(ba, QuantBase::Packed(_)),
                        matches!(bb, QuantBase::Packed(_)),
                        "{tag} cfg {ci} {na}: packedness differs"
                    );
                }
                _ => panic!("{tag} cfg {ci} {na}: unexpected op shape"),
            }
        }
        for (ma, mb) in oa.meta.iter().zip(&ob.meta) {
            assert_eq!(ma.k_star, mb.k_star, "{tag} cfg {ci}: k* differs");
        }
        for (ra, rb) in oa.reports.iter().zip(&ob.reports) {
            assert_eq!(
                ra.weight_err.to_bits(),
                rb.weight_err.to_bits(),
                "{tag} cfg {ci} {}: weight_err differs",
                ra.name
            );
            assert_eq!(
                ra.scaled_err.to_bits(),
                rb.scaled_err.to_bits(),
                "{tag} cfg {ci} {}: scaled_err differs",
                ra.name
            );
        }
    }
}

/// Tentpole acceptance: sweep outcomes and fleet PPLs through N ∈
/// {1, 2, 4} worker processes are bit-identical to the in-process path,
/// and the wire preserves the lock-step grouping (shared packed bases).
#[test]
fn sharded_sweep_and_fleet_bit_identical_n_1_2_4() {
    let (params, cfg, calib, eval_batches) = setup();
    let configs = grid();
    let metrics = Metrics::new();
    let expect = SweepRunner::new(&params, &cfg, &calib, &metrics).run_factored(&configs);
    let exp_models: Vec<&FactoredModel> = expect.iter().map(|o| &o.model).collect();
    let exp_ppl =
        fleet_perplexity(&exp_models, &cfg, &eval_batches, 2, cfg.seq_len).expect("fleet");

    for n in [1usize, 2, 4] {
        let mut session = ShardSession::spawn(&shard_opts(n)).expect("spawn workers");
        let runner = ShardedSweepRunner::new(&params, &cfg, &calib, &metrics);
        let outs = runner.run_factored(&mut session, &configs).expect("sharded sweep");
        assert_outcomes_identical(&format!("N={n}"), &expect, &outs);

        let models: Vec<&FactoredModel> = outs.iter().map(|o| &o.model).collect();
        // grid dedup / lock-step groups survive the wire round-trip
        assert_eq!(
            group_by_shared_bases(&exp_models),
            group_by_shared_bases(&models),
            "N={n}: lock-step grouping changed"
        );
        let ppl = fleet_perplexity_sharded(
            &mut session,
            &models,
            &cfg,
            &eval_batches,
            2,
            cfg.seq_len,
            &metrics,
        )
        .expect("sharded fleet");
        for (i, (a, b)) in exp_ppl.iter().zip(&ppl).enumerate() {
            assert_eq!(a.to_bits(), b.to_bits(), "N={n} model {i}: ppl {a} vs {b}");
        }
        session.shutdown();
    }
}

/// Worker-death requeue: the first of two workers exits after 2 jobs
/// (an abrupt EOF from the host's perspective); its in-flight jobs move
/// to the survivor and the merged result is still bit-identical.
#[test]
fn worker_death_requeues_bit_identically() {
    let (params, cfg, calib, eval_batches) = setup();
    let configs = grid();
    let metrics = Metrics::new();
    let expect = SweepRunner::new(&params, &cfg, &calib, &metrics).run_factored(&configs);

    let opts = ShardOptions { exit_after_first: Some(2), ..shard_opts(2) };
    let mut session = ShardSession::spawn(&opts).expect("spawn workers");
    let runner = ShardedSweepRunner::new(&params, &cfg, &calib, &metrics);
    let outs = runner.run_factored(&mut session, &configs).expect("sharded sweep with a death");
    assert_outcomes_identical("death", &expect, &outs);
    assert_eq!(session.n_alive(), 1, "worker 0 must have died");
    assert!(
        metrics.get("shard.worker_deaths") >= 1.0,
        "death not recorded: {}",
        metrics.get("shard.worker_deaths")
    );

    // the surviving worker also carries the fleet batch afterwards
    let models: Vec<&FactoredModel> = outs.iter().map(|o| &o.model).collect();
    let exp_models: Vec<&FactoredModel> = expect.iter().map(|o| &o.model).collect();
    let exp_ppl =
        fleet_perplexity(&exp_models, &cfg, &eval_batches, 2, cfg.seq_len).expect("fleet");
    let ppl = fleet_perplexity_sharded(
        &mut session,
        &models,
        &cfg,
        &eval_batches,
        2,
        cfg.seq_len,
        &metrics,
    )
    .expect("fleet on survivor");
    for (a, b) in exp_ppl.iter().zip(&ppl) {
        assert_eq!(a.to_bits(), b.to_bits());
    }
    session.shutdown();
}

/// When every worker dies before finishing, the host errors out instead
/// of hanging (the pop_timeout liveness probe catches even a worker that
/// exits without a clean EOF handshake).
#[test]
fn all_workers_dead_is_an_error_not_a_hang() {
    let (params, cfg, calib, _) = setup();
    let configs = grid();
    let metrics = Metrics::new();
    let opts = ShardOptions { exit_after_first: Some(1), ..shard_opts(1) };
    let mut session = ShardSession::spawn(&opts).expect("spawn worker");
    let runner = ShardedSweepRunner::new(&params, &cfg, &calib, &metrics);
    let err = runner
        .run_factored(&mut session, &configs)
        .expect_err("single worker dying after one job must fail the run");
    assert!(
        err.to_string().contains("all shard workers died"),
        "unexpected error: {err:#}"
    );
}

/// An empty grid never spawns work and mirrors the in-process shape.
#[test]
fn empty_grid_is_a_noop_without_worker_traffic() {
    let (params, cfg, calib, _) = setup();
    let metrics = Metrics::new();
    let mut session = ShardSession::spawn(&shard_opts(1)).expect("spawn worker");
    let runner = ShardedSweepRunner::new(&params, &cfg, &calib, &metrics);
    let outs = runner.run_factored(&mut session, &[]).expect("empty grid");
    assert!(outs.is_empty());
    session.shutdown();
}

// ---------------------------------------------------------------------------
// TCP transport (satellites: loopback bit-identity, mid-job worker death,
// handshake refusal)
// ---------------------------------------------------------------------------

/// Satellite acceptance: the TCP transport (real worker processes
/// dialing a loopback socket) is bit-identical to both the pipe
/// transport and the in-process engines for N ∈ {1, 2, 4} — sweep
/// outcomes, lock-step grouping, and fleet PPLs.
#[test]
fn tcp_loopback_sharded_bit_identical_n_1_2_4() {
    let (params, cfg, calib, eval_batches) = setup();
    let configs = grid();
    let metrics = Metrics::new();
    let expect = SweepRunner::new(&params, &cfg, &calib, &metrics).run_factored(&configs);
    let exp_models: Vec<&FactoredModel> = expect.iter().map(|o| &o.model).collect();
    let exp_ppl =
        fleet_perplexity(&exp_models, &cfg, &eval_batches, 2, cfg.seq_len).expect("fleet");

    for n in [1usize, 2, 4] {
        let mut session = ShardSession::spawn_tcp(&shard_opts(n)).expect("spawn TCP workers");
        let runner = ShardedSweepRunner::new(&params, &cfg, &calib, &metrics);
        let outs = runner.run_factored(&mut session, &configs).expect("TCP sharded sweep");
        assert_outcomes_identical(&format!("tcp N={n}"), &expect, &outs);

        let models: Vec<&FactoredModel> = outs.iter().map(|o| &o.model).collect();
        assert_eq!(
            group_by_shared_bases(&exp_models),
            group_by_shared_bases(&models),
            "tcp N={n}: lock-step grouping changed"
        );
        let ppl = fleet_perplexity_sharded(
            &mut session,
            &models,
            &cfg,
            &eval_batches,
            2,
            cfg.seq_len,
            &metrics,
        )
        .expect("TCP sharded fleet");
        for (i, (a, b)) in exp_ppl.iter().zip(&ppl).enumerate() {
            assert_eq!(a.to_bits(), b.to_bits(), "tcp N={n} model {i}: ppl {a} vs {b}");
        }
        session.shutdown();
    }
}

/// Satellite: a TCP worker that dies mid-run (a real process on a
/// loopback socket, exiting after 2 jobs without any shutdown
/// handshake) is noticed — reader FIN plus the `pop_timeout` child
/// probe — its in-flight jobs requeue onto the survivor, and the merged
/// results still match the in-process engines bit-for-bit.
#[test]
fn tcp_worker_killed_mid_job_requeues_bit_identically() {
    let (params, cfg, calib, eval_batches) = setup();
    let configs = grid();
    let metrics = Metrics::new();
    let expect = SweepRunner::new(&params, &cfg, &calib, &metrics).run_factored(&configs);

    let opts = ShardOptions { exit_after_first: Some(2), ..shard_opts(2) };
    let mut session = ShardSession::spawn_tcp(&opts).expect("spawn TCP workers");
    let runner = ShardedSweepRunner::new(&params, &cfg, &calib, &metrics);
    let outs = runner.run_factored(&mut session, &configs).expect("TCP sweep with a death");
    assert_outcomes_identical("tcp death", &expect, &outs);
    assert_eq!(session.n_alive(), 1, "worker 0 must have died");
    assert!(
        metrics.get("shard.worker_deaths") >= 1.0,
        "death not recorded: {}",
        metrics.get("shard.worker_deaths")
    );
    assert!(
        metrics.get("shard.requeued") >= 1.0,
        "no jobs requeued: {}",
        metrics.get("shard.requeued")
    );

    // the surviving TCP worker also carries the fleet batch afterwards
    let models: Vec<&FactoredModel> = outs.iter().map(|o| &o.model).collect();
    let exp_models: Vec<&FactoredModel> = expect.iter().map(|o| &o.model).collect();
    let exp_ppl =
        fleet_perplexity(&exp_models, &cfg, &eval_batches, 2, cfg.seq_len).expect("fleet");
    let ppl = fleet_perplexity_sharded(
        &mut session,
        &models,
        &cfg,
        &eval_batches,
        2,
        cfg.seq_len,
        &metrics,
    )
    .expect("fleet on TCP survivor");
    for (a, b) in exp_ppl.iter().zip(&ppl) {
        assert_eq!(a.to_bits(), b.to_bits());
    }
    session.shutdown();
}

/// Satellite: the TCP handshake refuses a peer speaking another wire
/// version — the connection is dropped without counting toward the
/// expected worker set — while a well-versioned worker on the same
/// listener is admitted and serves jobs.
#[test]
fn tcp_handshake_refuses_version_mismatch() {
    use srr::coordinator::wire::{encode_hello, WIRE_VERSION};
    use srr::coordinator::{ShardHost, Transport};
    use std::io::Write;

    // refusal alone: a stale client is never admitted, so the accept
    // deadline expires with zero workers
    let host = ShardHost::bind("127.0.0.1:0").expect("bind");
    let addr = host.local_addr().expect("addr").to_string();
    let stale = {
        let addr = addr.clone();
        std::thread::spawn(move || {
            let mut s = std::net::TcpStream::connect(addr).expect("connect");
            let mut bytes = Vec::new();
            encode_hello(true, 0).write_to(&mut bytes).unwrap();
            // advertise a future wire version in the frame header
            bytes[4..6].copy_from_slice(&(WIRE_VERSION + 1).to_le_bytes());
            s.write_all(&bytes).unwrap();
            // hold the socket open until the host refuses (EOF/RST)
            let _ = std::io::Read::read(&mut s, &mut [0u8; 16]);
        })
    };
    let err = match host.accept_workers(1, std::time::Duration::from_millis(1500)) {
        Ok(_) => panic!("a cross-version peer must not be admitted"),
        Err(e) => e,
    };
    assert!(
        err.to_string().contains("0/1 workers connected"),
        "unexpected error: {err:#}"
    );
    drop(host); // release the listener so the stale peer unblocks
    stale.join().unwrap();

    // the same listener still admits a well-versioned real worker
    let host = ShardHost::bind("127.0.0.1:0").expect("bind");
    let addr = host.local_addr().expect("addr").to_string();
    let stale2 = {
        let addr = addr.clone();
        std::thread::spawn(move || {
            let mut s = std::net::TcpStream::connect(addr).expect("connect");
            let mut bytes = Vec::new();
            encode_hello(true, 0).write_to(&mut bytes).unwrap();
            bytes[4..6].copy_from_slice(&(WIRE_VERSION + 1).to_le_bytes());
            s.write_all(&bytes).unwrap();
            let _ = std::io::Read::read(&mut s, &mut [0u8; 16]);
        })
    };
    let mut worker = std::process::Command::new(env!("CARGO_BIN_EXE_srr"))
        .arg("shard-worker")
        .arg("--connect")
        .arg(&addr)
        .stdin(std::process::Stdio::null())
        .stdout(std::process::Stdio::null())
        .spawn()
        .expect("spawn worker");
    let accepted = host
        .accept_workers(1, std::time::Duration::from_secs(30))
        .expect("good worker admitted despite the stale peer");
    assert_eq!(accepted.len(), 1);
    drop(host); // unblock the stale peer if it was never accepted
    stale2.join().unwrap();

    // the admitted connection serves real jobs end to end
    let (params, cfg, calib, _) = setup();
    let configs: Vec<_> = grid().into_iter().take(2).collect();
    let metrics = Metrics::new();
    let expect = SweepRunner::new(&params, &cfg, &calib, &metrics).run_factored(&configs);
    let mut session = ShardSession::from_transports(
        accepted.into_iter().map(|t| Box::new(t) as Box<dyn Transport>).collect(),
    )
    .expect("session over the admitted worker");
    let runner = ShardedSweepRunner::new(&params, &cfg, &calib, &metrics);
    let outs = runner.run_factored(&mut session, &configs).expect("sweep over dial-in");
    assert_outcomes_identical("dial-in", &expect, &outs);
    session.shutdown();
    let _ = worker.wait();
}

/// Tentpole acceptance (elasticity): a real `srr shard-worker --connect`
/// process dialing in *mid-run* is admitted by the host's still-open
/// accept loop, the merged sweep stays bit-identical, and the grown
/// fleet then serves the fleet-PPL batch — also bit-identically.
#[test]
fn mid_run_connect_join_admits_worker_and_stays_bit_identical() {
    use srr::coordinator::{ShardHost, Transport};
    use std::time::Duration;

    let (params, cfg, calib, eval_batches) = setup();
    let configs = grid();
    let metrics = Metrics::new();
    let expect = SweepRunner::new(&params, &cfg, &calib, &metrics).run_factored(&configs);

    let host = ShardHost::bind("127.0.0.1:0").expect("bind");
    let addr = host.local_addr().expect("addr").to_string();
    let spawn_worker = |addr: &str| {
        std::process::Command::new(env!("CARGO_BIN_EXE_srr"))
            .arg("shard-worker")
            .arg("--connect")
            .arg(addr)
            .stdin(std::process::Stdio::null())
            .stdout(std::process::Stdio::null())
            .spawn()
            .expect("spawn worker")
    };

    // assemble a one-worker fleet, then keep the listener open — the
    // by-hand equivalent of `ShardSession::listen` on an ephemeral port
    let mut first = spawn_worker(&addr);
    let accepted = host
        .accept_workers(1, Duration::from_secs(30))
        .expect("first worker dials in");
    let mut session = ShardSession::from_transports(
        accepted.into_iter().map(|t| Box::new(t) as Box<dyn Transport>).collect(),
    )
    .expect("session over the first worker");
    session.keep_accepting(host);
    assert_eq!(session.n_alive(), 1);

    // the joiner dials in while the sweep is running; the dispatcher's
    // accept loop admits it and feeds it from the live job queue
    let joiner = {
        let addr = addr.clone();
        std::thread::spawn(move || {
            std::thread::sleep(Duration::from_millis(200));
            spawn_worker(&addr)
        })
    };
    let runner = ShardedSweepRunner::new(&params, &cfg, &calib, &metrics);
    let outs = runner
        .run_factored(&mut session, &configs)
        .expect("sweep with a mid-run joiner");
    assert_outcomes_identical("mid-run join", &expect, &outs);
    let mut second = joiner.join().unwrap();

    // a short grid can drain before the joiner's handshake lands — poll
    // the between-batch admission path until the fleet has grown
    let t0 = std::time::Instant::now();
    loop {
        session.admit_pending_joins();
        if session.n_alive() >= 2 {
            break;
        }
        assert!(
            t0.elapsed() < Duration::from_secs(30),
            "joiner never admitted (n_alive={})",
            session.n_alive()
        );
        std::thread::sleep(Duration::from_millis(20));
    }

    // the grown fleet (incumbent + joiner) carries the fleet batch
    let models: Vec<&FactoredModel> = outs.iter().map(|o| &o.model).collect();
    let exp_models: Vec<&FactoredModel> = expect.iter().map(|o| &o.model).collect();
    let exp_ppl =
        fleet_perplexity(&exp_models, &cfg, &eval_batches, 2, cfg.seq_len).expect("fleet");
    let ppl = fleet_perplexity_sharded(
        &mut session,
        &models,
        &cfg,
        &eval_batches,
        2,
        cfg.seq_len,
        &metrics,
    )
    .expect("fleet over the grown fleet");
    for (i, (a, b)) in exp_ppl.iter().zip(&ppl).enumerate() {
        assert_eq!(a.to_bits(), b.to_bits(), "join model {i}: ppl {a} vs {b}");
    }
    session.shutdown();
    let _ = first.wait();
    let _ = second.wait();
}
