//! Multi-process PTQ sweep: shard the reconstruction grid and the fleet
//! perplexity evaluation across `srr shard-worker` processes.
//!
//! The host runs the shared-work preparation (scalings, Hessians, k=0
//! quantizations, spectra) in-process, then ships per-(layer, config)
//! reconstruction jobs — and fleet (group × batch) PPL jobs — to N
//! worker processes over the binary wire codec (`coordinator::wire`),
//! merging results deterministically by job id. Outcomes are
//! bit-identical to the single-process `SweepRunner` path; shared packed
//! bases are deduplicated on the wire by content hash, so the workers
//! see the same lock-step groups the in-process fleet evaluator uses.
//!
//!   cargo run --release --example shard_sweep -- [--workers 2] [--rank 8] [--tcp]
//!
//! Requires the `srr` binary (`cargo build --release`) so the host can
//! spawn workers; set `SRR_SHARD_BIN` if it lives somewhere unusual.
//! With `--tcp` the workers dial back over a loopback socket instead of
//! stdin/stdout pipes — the same transport remote workers use (see the
//! README's remote-worker workflow for the multi-host invocation).

use srr::coordinator::{
    fleet_perplexity_sharded, Metrics, QuantizerSpec, ShardOptions, ShardSession,
    ShardedSweepRunner, SweepConfig,
};
use srr::exp::ExpCtx;
use srr::qer::Method;
use srr::scaling::ScalingKind;
use srr::serve::FactoredModel;
use srr::util::cli::Args;

fn main() -> anyhow::Result<()> {
    let args = Args::parse(std::env::args().skip(1));
    let workers = args.get_usize("workers", 2);
    let rank = args.get_usize("rank", 8);

    let mut ctx = match ExpCtx::new(true) {
        Ok(ctx) => ctx,
        Err(e) => {
            eprintln!("[no artifacts ({e:#}); offline mode — untrained synthetic fixture]");
            ExpCtx::offline(true)?
        }
    };
    let fx = ctx.lm("tiny")?;

    // a small Table-1-shaped grid: w-only + plain-QER ranks (shared
    // packed base → one lock-step eval group) + the SRR method
    let quant = QuantizerSpec::Mxint { bits: 3, block: 32 };
    let mut configs = vec![SweepConfig::new(quant, Method::WOnly, 0, ScalingKind::Identity)];
    for r in [rank / 2, rank] {
        configs.push(SweepConfig::new(quant, Method::Qer, r.max(1), ScalingKind::DiagRms));
    }
    configs.push(SweepConfig::new(quant, Method::QerSrr, rank, ScalingKind::DiagRms));

    let opts = ShardOptions::with_workers(workers);
    let mut session = if args.has_flag("tcp") {
        println!("spawning {workers} shard worker(s) over TCP loopback…");
        ShardSession::spawn_tcp(&opts)?
    } else {
        println!("spawning {workers} shard worker(s) over pipes…");
        ShardSession::spawn(&opts)?
    };
    let metrics = Metrics::new();
    let runner = ShardedSweepRunner::new(&fx.params, &fx.cfg, &fx.calib, &metrics);
    let outcomes = runner.run_factored(&mut session, &configs)?;
    println!(
        "sweep done: {} outcomes, {} jobs over {} worker(s), {} bytes shipped",
        outcomes.len(),
        metrics.get("shard.jobs_sent"),
        workers,
        metrics.get("shard.tx_bytes") as u64,
    );

    let models: Vec<&FactoredModel> = outcomes.iter().map(|o| &o.model).collect();
    let b = 2;
    let t = fx.cfg.seq_len;
    let batches: Vec<Vec<i32>> = (0..4).map(|i| fx.corpus.train_batch(b, t, 30 + i)).collect();
    let ppl = fleet_perplexity_sharded(&mut session, &models, &fx.cfg, &batches, b, t, &metrics)?;
    for (i, (c, p)) in configs.iter().zip(&ppl).enumerate() {
        println!("  {:32} ppl {p:8.3}  mean k* {:.1}", c.label, outcomes[i].mean_k_star());
    }
    session.shutdown();
    Ok(())
}
