//! End-to-end driver (DESIGN.md §validation): proves all three layers
//! compose on a real small workload.
//!
//! 1. **Train** the `small` transformer LM from scratch for a few hundred
//!    steps on the synthetic Zipf-Markov corpus — rust drives the AOT
//!    `lm_train_small` artifact (jax.value_and_grad lowered once; the
//!    attention forward inside `lm_nll` runs the Pallas kernel), AdamW
//!    lives in rust, and the loss curve is logged.
//! 2. **Calibrate** on held-in data via the rust-native forward hooks.
//! 3. **Quantize** the trained model with 3-bit MXINT: w-only vs
//!    QERA-exact vs QERA-exact+SRR.
//! 4. **Evaluate** held-out perplexity for each variant through PJRT.
//!
//! Recorded in EXPERIMENTS.md §End-to-end.
//!
//!   cargo run --release --example e2e_train_quantize -- [--steps 300] [--model small]

use srr::coordinator::{run_ptq, Metrics, QuantizerSpec};
use srr::data::Corpus;
use srr::eval::perplexity;
use srr::model::{collect_calibration, synth_lm_params, Params};
use srr::qer::{Method, QerConfig};
use srr::qpeft::AdamW;
use srr::runtime::{Engine, Executor, TensorValue};
use srr::scaling::ScalingKind;
use srr::tensor::Mat;
use srr::util::cli::Args;

fn main() -> anyhow::Result<()> {
    let args = Args::parse(std::env::args().skip(1));
    let model = args.get_or("model", "small").to_string();
    let steps = args.get_usize("steps", 300);
    let lr = args.get_f64("lr", 3e-3) as f32;

    let engine = Engine::discover()?;
    let cfg = engine.manifest().model(&model)?.clone();
    let b = engine.manifest().lm_batch;
    let t = cfg.seq_len;

    // fresh init (synthetic spectra only shape the *starting point*;
    // training makes this a genuinely fitted model)
    let mut params = synth_lm_params(&cfg, 7, cfg.vocab);
    let n_params = params.count();
    println!("e2e: training model={model} (~{:.2}M params) for {steps} steps, b={b} t={t}", n_params as f64 / 1e6);

    let corpus = Corpus::generate(cfg.vocab, 200_000, 99);
    let order = Params::param_order(&cfg);
    let train_artifact = format!("lm_train_{model}");

    let mats: Vec<Mat> = order
        .iter()
        .map(|n| {
            let v = params.get(n).unwrap();
            let sh = v.shape();
            if sh.len() == 1 {
                Mat::from_vec(1, sh[0], v.as_f32().to_vec())
            } else {
                v.to_mat()
            }
        })
        .collect();
    let mut opt = AdamW::for_mats(lr, &mats.iter().collect::<Vec<_>>());
    opt.weight_decay = 0.0;
    let mut mats = mats;

    let t_start = std::time::Instant::now();
    let mut first_loss = None;
    let mut last_loss = 0.0f32;
    for step in 0..steps {
        // rebuild positional inputs from the optimizer state
        let mut inputs: Vec<TensorValue> = order
            .iter()
            .zip(&mats)
            .map(|(n, m)| {
                let sh = Params::param_shape(n, &cfg, cfg.vocab);
                TensorValue::f32(sh, m.data.clone())
            })
            .collect();
        let batch = corpus.train_batch(b, t, step);
        inputs.push(TensorValue::i32(vec![b, t], batch));
        let outs = engine.run(&train_artifact, &inputs)?;
        let loss = outs[0].scalar();
        first_loss.get_or_insert(loss);
        last_loss = loss;
        let grads: Vec<Mat> = outs[1..]
            .iter()
            .zip(&mats)
            .map(|(g, m)| Mat::from_vec(m.rows, m.cols, g.as_f32().to_vec()))
            .collect();
        let grad_refs: Vec<&Mat> = grads.iter().collect();
        let mut mat_refs: Vec<&mut Mat> = mats.iter_mut().collect();
        opt.update(&mut mat_refs, &grad_refs);
        if step % 25 == 0 || step + 1 == steps {
            println!("  step {step:4}  loss {loss:.4}  ({:.1}s)", t_start.elapsed().as_secs_f64());
        }
    }
    println!(
        "trained: loss {:.4} -> {:.4} in {:.1}s\n",
        first_loss.unwrap(),
        last_loss,
        t_start.elapsed().as_secs_f64()
    );
    assert!(last_loss < first_loss.unwrap(), "training must reduce loss");

    // write trained weights back into Params
    for (n, m) in order.iter().zip(&mats) {
        let sh = Params::param_shape(n, &cfg, cfg.vocab);
        params.set(n, TensorValue::f32(sh, m.data.clone()));
    }

    // held-out PPL of the trained model
    let eval_batches: Vec<Vec<i32>> = corpus.eval_batches(b, t).into_iter().take(8).collect();
    let artifact = format!("lm_nll_{model}");
    let ppl_fp = perplexity(&engine, &artifact, &params, &eval_batches, b, t)?;
    println!("BF16 PPL (held-out) = {ppl_fp:.3}  (vocab {} -> uniform PPL {})", cfg.vocab, cfg.vocab);

    // calibrate on train split via the rust-native forward hooks
    let calib_batches: Vec<Vec<i32>> = (0..12).map(|i| corpus.train_batch(b, t, 50_000 + i)).collect();
    let calib = collect_calibration(&params, &cfg, &calib_batches, b, t, 2 * cfg.d_ff);

    // quantize the *trained* model three ways and compare PPL
    let quant = QuantizerSpec::Mxint { bits: 3, block: 32 };
    println!("\n3-bit MXINT quantization of the trained model (rank 8):");
    for (label, method, scaling) in [
        ("w-only", Method::WOnly, ScalingKind::Identity),
        ("QERA-exact", Method::Qer, ScalingKind::Exact),
        ("QERA-exact + SRR", Method::QerSrr, ScalingKind::Exact),
    ] {
        let metrics = Metrics::new();
        let cfgq = QerConfig::new(method, 8, scaling);
        let out = run_ptq(&params, &cfg, &calib, quant, &cfgq, &metrics);
        let ppl = perplexity(&engine, &artifact, &out.params, &eval_batches, b, t)?;
        println!("  {label:<18} PPL = {ppl:.3}  (mean k* = {:.1})", out.mean_k_star());
    }
    println!("\ne2e OK");
    Ok(())
}
