//! Quickstart: quantize one weight matrix with SRR and inspect the
//! preserve/reconstruct split — the paper's Algorithm 1 in ~40 lines.
//!
//!   cargo run --release --example quickstart

use srr::qer::{reconstruct, Method, QerConfig};
use srr::quant::{MxintQuantizer, QuantCtx, Quantizer};
use srr::scaling::{Scaling, ScalingKind};
use srr::tensor::Mat;
use srr::util::Rng;

fn main() {
    // An anisotropic weight with outlier directions — the structure real
    // transformer projections exhibit (and the reason preserve-then-
    // quantize beats residual-only reconstruction).
    let mut rng = Rng::new(42);
    let w = srr::model::spectral_matrix_spiked(256, 256, 0.8, 4, 6.0, 0.06, &mut rng);

    let quantizer = MxintQuantizer::new(3, 32); // 3-bit MXINT, block 32
    let scaling = Scaling::Identity; // plug in activation scalings freely
    let ctx = QuantCtx::default();
    let rank = 8;

    println!("W: 256x256, 3-bit MXINT ({:.2} effective bits), rank budget {rank}\n",
             quantizer.effective_bits());

    for method in [Method::WOnly, Method::Qer, Method::QerSrr] {
        let cfg = QerConfig::new(method, rank, ScalingKind::Identity);
        let res = reconstruct(&w, &quantizer, &scaling, &ctx, &cfg);
        println!(
            "{:10}  ‖W − Q − LR‖_F = {:.4}   k* = {}",
            method.label(),
            res.weight_error(&w),
            res.k_star
        );
    }

    // Inspect the SRR split directly
    let cfg = QerConfig::new(Method::QerSrr, rank, ScalingKind::Identity);
    let res = reconstruct(&w, &quantizer, &scaling, &ctx, &cfg);
    let sel = res.selection.as_ref().unwrap();
    println!("\nsurrogate objective ρ_k(SW)·ρ_(r−k)(SE) over k:");
    for (k, obj) in sel.objective.iter().enumerate() {
        let marker = if k == res.k_star { "  <- k*" } else { "" };
        println!("  k={k}: {obj:.4}{marker}");
    }

    // Sanity: reconstruction error must not exceed plain quantization
    let wonly = MxintQuantizer::new(3, 32).quantize(&w, &ctx);
    assert!(res.weight_error(&w) <= w.sub(&wonly).frob());
    println!("\nquickstart OK");
    let _ = Mat::eye(1);
}
