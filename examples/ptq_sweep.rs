//! PTQ sweep: quantize a full synthetic LM with every QER method and
//! evaluate perplexity through the AOT-compiled forward (PJRT) — a
//! miniature of the paper's Table 1 protocol on one model.
//!
//! The whole grid runs through `coordinator::run_sweep`, so the per-layer
//! scalings, Hessians and scaled-weight SVDs are computed once and shared
//! across every method/rank cell (bit-identical to per-config `run_ptq`).
//!
//!   cargo run --release --example ptq_sweep -- [--model tiny] [--rank 8]

use srr::coordinator::{run_sweep, Metrics, QuantizerSpec, SweepConfig};
use srr::eval::perplexity;
use srr::exp::ExpCtx;
use srr::qer::Method;
use srr::runtime::Executor;
use srr::scaling::ScalingKind;
use srr::util::cli::Args;

fn main() -> anyhow::Result<()> {
    let args = Args::parse(std::env::args().skip(1));
    let model = args.get_or("model", "tiny").to_string();
    let rank = args.get_usize("rank", 8);

    let mut ctx = ExpCtx::new(false)?;
    let fx = ctx.lm(&model)?;
    let b = ctx.engine.manifest().lm_batch;
    let t = fx.cfg.seq_len;
    let batches = ctx.ppl_batches(&model)?;
    let artifact = format!("lm_nll_{model}");

    let bf16 = perplexity(&ctx.engine, &artifact, &fx.params.clone(), &batches, b, t)?;
    println!("model={model} rank={rank}  BF16 PPL = {bf16:.3}\n");
    println!("{:<28} {:>10} {:>8}", "method", "PPL", "mean k*");

    let quant = QuantizerSpec::Mxint { bits: 3, block: 32 };
    let grid: Vec<(&str, Method, ScalingKind)> = vec![
        ("w-only", Method::WOnly, ScalingKind::Identity),
        ("ZeroQuant-V2 (S=I)", Method::Qer, ScalingKind::Identity),
        ("LQER", Method::Qer, ScalingKind::DiagRms),
        ("LQER + SRR", Method::QerSrr, ScalingKind::DiagRms),
        ("QERA-approx", Method::Qer, ScalingKind::DiagAbsMean),
        ("QERA-approx + SRR", Method::QerSrr, ScalingKind::DiagAbsMean),
        ("QERA-exact", Method::Qer, ScalingKind::Exact),
        ("QERA-exact + SRR", Method::QerSrr, ScalingKind::Exact),
        ("preserve-only (k=r)", Method::PreserveOnly, ScalingKind::Exact),
        ("fixed split k=r/2", Method::FixedSplitHalf, ScalingKind::Exact),
        ("SRR eq.(6) variant", Method::SrrSingleSvd, ScalingKind::Exact),
    ];
    let configs: Vec<SweepConfig> = grid
        .iter()
        .map(|(label, method, scaling)| {
            let r = if *method == Method::WOnly { 0 } else { rank };
            SweepConfig::new(quant, *method, r, *scaling).labeled(label)
        })
        .collect();

    let metrics = Metrics::new();
    let outs = run_sweep(&fx.params, &fx.cfg, &fx.calib, &configs, &metrics);
    for (c, out) in configs.iter().zip(&outs) {
        let ppl = perplexity(&ctx.engine, &artifact, &out.params, &batches, b, t)?;
        println!("{:<28} {ppl:>10.3} {:>8.1}", c.label, out.mean_k_star());
    }
    println!(
        "\nshared-work: {} cache entries, prep {:.2}s, fan-out {:.2}s",
        metrics.get("sweep.cache_entries"),
        metrics.get("sweep.prep_secs"),
        metrics.get("sweep.reconstruct_secs")
    );
    Ok(())
}
