//! PTQ sweep: quantize a full synthetic LM with every QER method and
//! evaluate perplexity — a miniature of the paper's Table 1 protocol on
//! one model, runnable from a fresh clone with no PJRT artifacts.
//!
//! The whole grid runs through `coordinator::run_sweep_factored`, so the
//! per-layer scalings, Hessians and scaled-weight SVDs are computed once
//! and shared across every method/rank cell, and the outcomes come back
//! *factored*: bit-packed bases + adapters, with rank/scaling variants
//! of one quantization sharing their base buffers through `Arc`. Scoring
//! then goes through the fleet evaluator (`eval::fleet_perplexity`):
//! outcomes that share bases forward in one lock-step pass, decoding
//! each packed base once per group per batch.
//!
//!   cargo run --release --example ptq_sweep -- [--model tiny] [--rank 8]

use srr::coordinator::{run_sweep_factored, Metrics, QuantizerSpec, SweepConfig};
use srr::eval::{fleet_footprint, fleet_perplexity, perplexity_native};
use srr::exp::ExpCtx;
use srr::qer::Method;
use srr::runtime::Executor;
use srr::scaling::ScalingKind;
use srr::serve::FactoredModel;
use srr::util::cli::Args;

fn main() -> anyhow::Result<()> {
    let args = Args::parse(std::env::args().skip(1));
    let model = args.get_or("model", "tiny").to_string();
    let rank = args.get_usize("rank", 8);

    // with artifacts the fixture model is trained first; without, the
    // offline context still runs the whole sweep + eval rust-natively
    let mut ctx = match ExpCtx::new(false) {
        Ok(ctx) => ctx,
        Err(e) => {
            eprintln!("[no artifacts ({e:#}); offline mode — untrained synthetic fixture]");
            ExpCtx::offline(false)?
        }
    };
    let fx = ctx.lm(&model)?;
    let b = ctx.engine.manifest().lm_batch;
    let t = fx.cfg.seq_len;
    let batches = ctx.ppl_batches(&model)?;

    let bf16 = perplexity_native(&fx.params, &fx.cfg, &batches, b, t);
    println!("model={model} rank={rank}  BF16 PPL = {bf16:.3}\n");
    println!("{:<28} {:>10} {:>8}", "method", "PPL", "mean k*");

    let quant = QuantizerSpec::Mxint { bits: 3, block: 32 };
    let grid: Vec<(&str, Method, ScalingKind)> = vec![
        ("w-only", Method::WOnly, ScalingKind::Identity),
        ("ZeroQuant-V2 (S=I)", Method::Qer, ScalingKind::Identity),
        ("LQER", Method::Qer, ScalingKind::DiagRms),
        ("LQER + SRR", Method::QerSrr, ScalingKind::DiagRms),
        ("QERA-approx", Method::Qer, ScalingKind::DiagAbsMean),
        ("QERA-approx + SRR", Method::QerSrr, ScalingKind::DiagAbsMean),
        ("QERA-exact", Method::Qer, ScalingKind::Exact),
        ("QERA-exact + SRR", Method::QerSrr, ScalingKind::Exact),
        ("preserve-only (k=r)", Method::PreserveOnly, ScalingKind::Exact),
        ("fixed split k=r/2", Method::FixedSplitHalf, ScalingKind::Exact),
        ("SRR eq.(6) variant", Method::SrrSingleSvd, ScalingKind::Exact),
    ];
    let configs: Vec<SweepConfig> = grid
        .iter()
        .map(|(label, method, scaling)| {
            let r = if *method == Method::WOnly { 0 } else { rank };
            SweepConfig::new(quant, *method, r, *scaling).labeled(label)
        })
        .collect();

    let metrics = Metrics::new();
    let outs = run_sweep_factored(&fx.params, &fx.cfg, &fx.calib, &configs, &metrics);
    let models: Vec<&FactoredModel> = outs.iter().map(|o| &o.model).collect();
    let ppls = fleet_perplexity(&models, &fx.cfg, &batches, b, t);
    for ((c, out), ppl) in configs.iter().zip(&outs).zip(&ppls) {
        println!("{:<28} {ppl:>10.3} {:>8.1}", c.label, out.mean_k_star());
    }

    let fp = fleet_footprint(&models);
    println!(
        "\nshared-work: {} cache entries, prep {:.2}s, fan-out {:.2}s",
        metrics.get("sweep.cache_entries"),
        metrics.get("sweep.prep_secs"),
        metrics.get("sweep.reconstruct_secs")
    );
    println!(
        "fleet eval: {} outcomes in {} lock-step groups; packed bases {} bytes resident \
         (vs {} if unshared)",
        models.len(),
        fp.groups,
        fp.unique_base_bytes,
        fp.total_base_bytes
    );
    Ok(())
}
