//! QPEFT fine-tune: SRR-initialized adapters vs QLoRA on a GLUE-sim
//! task, with γ gradient scaling on the preserved directions —
//! the paper's §4.4 / Table 3 protocol on one task.
//!
//!   cargo run --release --example qpeft_finetune -- [--task RTE-sim] [--bits 2] [--steps 60]

use srr::coordinator::QuantizerSpec;
use srr::data::glue_sim::GlueTask;
use srr::eval::glue_score;
use srr::exp::ExpCtx;
use srr::qpeft::{init_qpeft, GradScale, QpeftInit, QpeftTrainer};
use srr::runtime::{Executor, TensorValue};
use srr::tensor::Mat;
use srr::util::cli::Args;
use srr::util::Rng;

fn main() -> anyhow::Result<()> {
    let args = Args::parse(std::env::args().skip(1));
    let task_name = args.get_or("task", "RTE-sim").to_string();
    let bits = args.get_usize("bits", 2) as u32;
    let steps = args.get_usize("steps", 60);
    let rank = if bits == 2 { 64 } else { 8 };

    let mut ctx = ExpCtx::new(false)?;
    let m = ctx.engine.manifest();
    let (batch, seq, classes) = (m.cls_batch, m.cls_seq, m.cls_classes);
    let vocab = m.model("tiny")?.vocab;
    let tasks = GlueTask::all(vocab, seq, 256, 64, 9090);
    let task = tasks
        .iter()
        .find(|t| t.name == task_name)
        .expect("unknown task")
        .clone();
    let fx = ctx.lm("tiny")?;
    let quant = QuantizerSpec::Mxint { bits, block: 32 };

    println!("task={task_name} bits={bits} rank={rank} steps={steps}\n");
    println!("{:<10} {:>10} {:>10}", "method", "final loss", "dev score");

    for (label, init, scale) in [
        ("QLoRA", QpeftInit::QLoRA, GradScale::None),
        ("QERA", QpeftInit::Qera, GradScale::None),
        ("SRR", QpeftInit::Srr, GradScale::Fixed { gamma: 0.1 }),
    ] {
        let mut rng = Rng::new(777);
        let head = Mat::randn(fx.cfg.d_model, classes, 0.02, &mut rng);
        let state = init_qpeft(&fx.params, &fx.cfg, &fx.calib, quant, init, rank, head, 0);
        let mut trainer = QpeftTrainer::new(
            &ctx.engine,
            &format!("qpeft_cls_train_tiny_r{rank}"),
            state,
            1e-3,
            scale,
        );
        for step in 0..steps {
            let (toks, labels, _) = GlueTask::batch(&task.train, step * batch, batch, seq);
            trainer.step(&[
                TensorValue::i32(vec![batch, seq], toks),
                TensorValue::i32(vec![batch], labels),
            ])?;
        }
        // dev eval
        let n_out = classes;
        let mut logits = vec![0.0f32; task.dev.len() * n_out];
        let mut i = 0;
        while i < task.dev.len() {
            let (toks, _, _) = GlueTask::batch(&task.dev, i, batch, seq);
            let out = trainer.eval(
                &format!("qpeft_cls_fwd_tiny_r{rank}"),
                &[TensorValue::i32(vec![batch, seq], toks)],
            )?;
            let data = out.as_f32();
            for row in 0..batch {
                if i + row < task.dev.len() {
                    logits[(i + row) * n_out..(i + row + 1) * n_out]
                        .copy_from_slice(&data[row * n_out..(row + 1) * n_out]);
                }
            }
            i += batch;
        }
        let score = glue_score(task.metric, &logits, n_out, &task.dev);
        println!("{label:<10} {:>10.4} {score:>10.2}", trainer.final_loss(8));
    }
    Ok(())
}
