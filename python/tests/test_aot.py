"""AOT catalog sanity: every entry's declared arg specs trace cleanly."""

import jax
import jax.numpy as jnp
import pytest

from compile import aot


@pytest.fixture(scope="module")
def catalog():
    return aot.build_catalog()


def test_catalog_names_unique(catalog):
    names = [c[0] for c in catalog]
    assert len(names) == len(set(names))


def test_catalog_covers_required_entry_points(catalog):
    names = {c[0] for c in catalog}
    required = {
        "lm_fwd_tiny", "lm_fwd_small", "lm_fwd_base",
        "lm_nll_tiny", "lm_nll_small", "lm_nll_base",
        "lm_train_tiny", "lm_train_small",
        "qpeft_lm_train_tiny_r8", "qpeft_lm_train_tiny_r64",
        "cls_train_tiny", "qpeft_cls_train_tiny_r8", "qpeft_cls_train_tiny_r64",
        "qpeft_cls_train_reg_tiny_r8", "qlr_lm_fwd_small_r64",
        "kernel_mxint2", "kernel_mxint3", "kernel_mxint4",
        "kernel_qlr", "kernel_attn",
    }
    missing = required - names
    assert not missing, f"missing artifacts: {missing}"


@pytest.mark.parametrize(
    "name",
    [
        "lm_nll_tiny",
        "lm_train_tiny",
        "qpeft_cls_train_tiny_r8",
        "qpeft_cls_fwd_reg_tiny_r8",
        "kernel_qlr",
    ],
)
def test_entry_traces_with_declared_specs(catalog, name):
    """eval_shape succeeds with exactly the declared positional args
    (catches arg-order drift between model.py and aot.py)."""
    entry = next(c for c in catalog if c[0] == name)
    _, fn, args, _ = entry
    specs = [jax.ShapeDtypeStruct(tuple(sh), aot.DTYPES[dt]) for (_, sh, dt) in args]
    outs = jax.eval_shape(fn, *specs)
    assert len(outs) >= 1
    for o in outs:
        assert all(isinstance(d, int) for d in o.shape)


def test_train_entry_grad_count(catalog):
    """A train artifact returns loss + one grad per trainable arg."""
    entry = next(c for c in catalog if c[0] == "qpeft_cls_train_tiny_r8")
    _, fn, args, _ = entry
    specs = [jax.ShapeDtypeStruct(tuple(sh), aot.DTYPES[dt]) for (_, sh, dt) in args]
    outs = jax.eval_shape(fn, *specs)
    n_adapters = sum(1 for (n, _, _) in args if n.endswith(".L") or n.endswith(".R"))
    assert len(outs) == 1 + n_adapters + 1  # loss + adapter grads + head grad


def test_fingerprint_changes_with_source(tmp_path, monkeypatch):
    fp1 = aot.source_fingerprint()
    assert isinstance(fp1, str) and len(fp1) == 64
