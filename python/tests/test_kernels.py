"""Kernel-vs-oracle correctness: the core L1 signal.

Each Pallas kernel (interpret=True) must match its pure-jnp oracle in
ref.py. Hypothesis sweeps shapes/bit-widths/block sizes; dedicated cases
cover the known edge behaviours (all-zero blocks, huge dynamic range,
clipping at the mantissa boundary, odd sequence lengths).
"""

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import attention, mxint_qdq, qlr_matmul
from compile.kernels.ref import attention_ref, mxint_qdq_ref, qlr_matmul_ref

RNG = np.random.default_rng(1234)


def randf(*shape, scale=1.0):
    return jnp.asarray(RNG.normal(size=shape).astype("float32") * scale)


# ---------------------------------------------------------------------------
# MXINT
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("bits", [2, 3, 4, 8])
@pytest.mark.parametrize("shape", [(8, 32), (24, 96), (128, 256), (5, 64)])
def test_mxint_matches_ref(bits, shape):
    w = randf(*shape)
    got = mxint_qdq(w, bits)
    want = mxint_qdq_ref(w, bits)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_mxint_zero_block_dequantizes_to_zero():
    w = jnp.zeros((4, 64), jnp.float32)
    assert float(jnp.max(jnp.abs(mxint_qdq(w, 3)))) == 0.0


def test_mxint_mixed_zero_and_nonzero_blocks():
    w = np.zeros((2, 64), dtype="float32")
    w[0, 32:] = RNG.normal(size=32)
    got = np.asarray(mxint_qdq(jnp.asarray(w), 3))
    assert np.all(got[:, :32] == 0.0) and np.all(got[1] == 0.0)
    assert np.any(got[0, 32:] != 0.0)


def test_mxint_huge_dynamic_range():
    w = randf(8, 64) * jnp.asarray(RNG.choice([1e-6, 1.0, 1e6], size=(8, 64)).astype("f4"))
    np.testing.assert_array_equal(
        np.asarray(mxint_qdq(w, 4)), np.asarray(mxint_qdq_ref(w, 4))
    )


def test_mxint_error_bound():
    """Per-element error <= one scale step.

    Non-clipped elements round to within scale/2; the block max can clip at
    the mantissa boundary (qmax*scale = 2^(E+1) - scale), adding at most one
    further step — so |w - deq| < scale everywhere (MXINT's known behaviour).
    """
    w = randf(16, 128)
    for bits in (3, 4, 6):
        deq = np.asarray(mxint_qdq_ref(w, bits))
        wb = np.asarray(w).reshape(16, -1, 32)
        maxabs = np.abs(wb).max(-1, keepdims=True)
        e = np.floor(np.log2(np.where(maxabs > 0, maxabs, 1.0)))
        scale = np.exp2(e - (bits - 2))
        err = np.abs(np.asarray(w).reshape(16, -1, 32) - deq.reshape(16, -1, 32))
        assert np.all(err <= scale + 1e-7)
        # and the non-clipped interior obeys the half-step bound
        interior = np.abs(wb) <= (2 ** (bits - 1) - 1) * scale
        assert np.all(np.where(interior, err, 0.0) <= scale / 2 + 1e-7)


def test_mxint_is_idempotent():
    w = randf(8, 64)
    once = mxint_qdq(w, 3)
    twice = mxint_qdq(once, 3)
    np.testing.assert_allclose(np.asarray(once), np.asarray(twice), atol=0)


@settings(max_examples=25, deadline=None)
@given(
    m=st.integers(1, 40),
    nb=st.integers(1, 6),
    bits=st.integers(2, 8),
    scale=st.sampled_from([1e-3, 1.0, 1e4]),
)
def test_mxint_hypothesis(m, nb, bits, scale):
    w = randf(m, nb * 32, scale=scale)
    np.testing.assert_array_equal(
        np.asarray(mxint_qdq(w, bits)), np.asarray(mxint_qdq_ref(w, bits))
    )


# ---------------------------------------------------------------------------
# fused QLR matmul
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("m,k,n,r", [(16, 96, 64, 8), (64, 256, 256, 64), (8, 32, 32, 4)])
def test_qlr_matches_ref(m, k, n, r):
    x, q, l, rr = randf(m, k), randf(k, n), randf(k, r), randf(r, n)
    got = qlr_matmul(x, q, l, rr)
    want = qlr_matmul_ref(x, q, l, rr)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-5, atol=2e-4)


def test_qlr_zero_adapter_equals_plain_matmul():
    x, q = randf(16, 64), randf(64, 48)
    l, r = jnp.zeros((64, 8)), jnp.zeros((8, 48))
    got = qlr_matmul(x, q, l, r)
    np.testing.assert_allclose(np.asarray(got), np.asarray(x @ q), rtol=1e-5, atol=1e-4)


@settings(max_examples=20, deadline=None)
@given(
    m=st.sampled_from([4, 16, 33, 64]),
    k=st.sampled_from([32, 96, 256]),
    n=st.sampled_from([32, 128]),
    r=st.sampled_from([1, 8, 64]),
)
def test_qlr_hypothesis(m, k, n, r):
    x, q, l, rr = randf(m, k), randf(k, n), randf(k, r), randf(r, n)
    np.testing.assert_allclose(
        np.asarray(qlr_matmul(x, q, l, rr)),
        np.asarray(qlr_matmul_ref(x, q, l, rr)),
        rtol=2e-5,
        atol=5e-4,
    )


# ---------------------------------------------------------------------------
# attention
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("causal", [True, False])
@pytest.mark.parametrize("b,h,t,dh", [(2, 4, 64, 32), (1, 2, 63, 16), (3, 1, 32, 8)])
def test_attention_matches_ref(causal, b, h, t, dh):
    q, k, v = randf(b, h, t, dh), randf(b, h, t, dh), randf(b, h, t, dh)
    got = attention(q, k, v, causal=causal)
    want = attention_ref(q, k, v, causal=causal)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-5, atol=2e-5)


def test_attention_first_token_is_value_when_causal():
    q, k, v = randf(1, 1, 16, 8), randf(1, 1, 16, 8), randf(1, 1, 16, 8)
    got = np.asarray(attention(q, k, v, causal=True))
    np.testing.assert_allclose(got[0, 0, 0], np.asarray(v)[0, 0, 0], rtol=1e-5, atol=1e-5)


@settings(max_examples=15, deadline=None)
@given(
    b=st.integers(1, 3),
    h=st.sampled_from([1, 2, 4]),
    t=st.sampled_from([8, 24, 63, 64]),
    dh=st.sampled_from([8, 16, 32]),
    causal=st.booleans(),
)
def test_attention_hypothesis(b, h, t, dh, causal):
    q, k, v = randf(b, h, t, dh), randf(b, h, t, dh), randf(b, h, t, dh)
    np.testing.assert_allclose(
        np.asarray(attention(q, k, v, causal=causal)),
        np.asarray(attention_ref(q, k, v, causal=causal)),
        rtol=3e-5,
        atol=3e-5,
    )
