"""L2 model correctness: shapes, gradients, QPEFT/dense equivalences."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from compile import model as M
from compile.configs import ModelCfg

MICRO = ModelCfg("micro", vocab=32, d_model=16, n_heads=2, n_layers=1, d_ff=32, seq_len=8)
RNG = np.random.default_rng(7)


def init_params(cfg, head="lm", n_classes=4, scale=0.05):
    out = []
    for n in M.param_names(cfg, head):
        sh = M.param_shape(n, cfg, head, n_classes)
        if len(sh) == 1:
            out.append(jnp.ones(sh, jnp.float32))
        else:
            out.append(jnp.asarray(RNG.normal(size=sh).astype("f4") * scale))
    return out


def tokens(cfg, b=2):
    return jnp.asarray(RNG.integers(0, cfg.vocab, size=(b, cfg.seq_len)).astype("i4"))


def test_param_names_order_and_shapes():
    names = M.param_names(MICRO)
    assert names[0] == "embed" and names[-1] == "head" and names[-2] == "norm_f"
    assert len(names) == 1 + 9 * MICRO.n_layers + 2
    assert M.param_shape("l0.down", MICRO) == (MICRO.d_ff, MICRO.d_model)
    assert M.param_shape("head", MICRO, "reg") == (MICRO.d_model, 1)
    assert len(M.linear_names(MICRO)) == 7 * MICRO.n_layers


def test_lm_fwd_shape_and_finite():
    ps = init_params(MICRO)
    (logits,) = M.lm_fwd(MICRO)(*ps, tokens(MICRO))
    assert logits.shape == (2, MICRO.seq_len, MICRO.vocab)
    assert bool(jnp.all(jnp.isfinite(logits)))


def test_lm_nll_mask_semantics():
    """Masked positions contribute nothing; count equals mask sum."""
    ps = init_params(MICRO)
    t = tokens(MICRO)
    full = jnp.ones_like(t, jnp.float32)
    half = full.at[:, 4:].set(0.0)
    nll_f, cnt_f = M.lm_nll(MICRO)(*ps, t, full)
    nll_h, cnt_h = M.lm_nll(MICRO)(*ps, t, half)
    assert cnt_f.shape == (2,)
    assert float(cnt_f[0]) == MICRO.seq_len - 1
    assert float(cnt_h[0]) == 3  # positions 1..3 of the shifted targets
    assert float(nll_h[0]) < float(nll_f[0])


def test_lm_train_matches_finite_difference():
    ps = init_params(MICRO)
    t = tokens(MICRO)
    out = M.lm_train(MICRO)(*ps, t)
    loss, grads = out[0], out[1:]
    assert np.isfinite(float(loss))
    # FD check on a handful of coordinates of the head matrix
    gi = M.param_names(MICRO).index("head")
    eps = 1e-3
    for idx in [(0, 0), (3, 7)]:
        bumped = list(ps)
        bumped[gi] = ps[gi].at[idx].add(eps)
        lp = M.lm_train(MICRO)(*bumped, t)[0]
        bumped[gi] = ps[gi].at[idx].add(-eps)
        lm = M.lm_train(MICRO)(*bumped, t)[0]
        fd = (float(lp) - float(lm)) / (2 * eps)
        np.testing.assert_allclose(float(grads[gi][idx]), fd, rtol=2e-2, atol=2e-4)


def test_sgd_step_decreases_loss():
    ps = init_params(MICRO)
    t = tokens(MICRO)
    step = jax.jit(M.lm_train(MICRO))
    out = step(*ps, t)
    loss0, grads = out[0], out[1:]
    ps2 = [p - 0.5 * g for p, g in zip(ps, grads)]
    loss1 = step(*ps2, t)[0]
    assert float(loss1) < float(loss0)


def qpeft_inputs(cfg, rank, head="cls", n_classes=4, zero_adapters=True):
    frozen = []
    for n in M.param_names(cfg, head)[:-1]:
        sh = M.param_shape(n, cfg, head, n_classes)
        frozen.append(
            jnp.ones(sh, jnp.float32)
            if len(sh) == 1
            else jnp.asarray(RNG.normal(size=sh).astype("f4") * 0.05)
        )
    adapters = []
    for n in M.linear_names(cfg):
        din, dout = M.param_shape(n, cfg)
        if zero_adapters:
            adapters += [jnp.zeros((din, rank)), jnp.zeros((rank, dout))]
        else:
            adapters += [
                jnp.asarray(RNG.normal(size=(din, rank)).astype("f4") * 0.05),
                jnp.asarray(RNG.normal(size=(rank, dout)).astype("f4") * 0.05),
            ]
    headw = jnp.asarray(
        RNG.normal(size=M.param_shape("head", cfg, head, n_classes)).astype("f4") * 0.05
    )
    return frozen, adapters, headw


def test_qpeft_zero_adapter_equals_dense_forward():
    """With Qdeq = W and L = R = 0, the QPEFT trunk must equal the dense trunk."""
    cfg, rank = MICRO, 4
    frozen, adapters, headw = qpeft_inputs(cfg, rank, zero_adapters=True)
    t = tokens(cfg)
    (logits_q,) = M.qpeft_cls_fwd(cfg, rank, "cls", 4)(*frozen, *adapters, headw, t)
    dense = frozen + [headw]
    (logits_d,) = M.cls_fwd(cfg, "cls", 4)(*dense, t)
    np.testing.assert_allclose(np.asarray(logits_q), np.asarray(logits_d), rtol=1e-5, atol=1e-5)


def test_qpeft_cls_train_outputs_and_grad_flow():
    cfg, rank = MICRO, 4
    frozen, adapters, headw = qpeft_inputs(cfg, rank, zero_adapters=False)
    t = tokens(cfg, b=3)
    labels = jnp.asarray(RNG.integers(0, 4, size=(3,)).astype("i4"))
    out = M.qpeft_cls_train(cfg, rank, "cls", 4)(*frozen, *adapters, headw, t, labels)
    loss, grads = out[0], out[1:]
    assert len(grads) == len(adapters) + 1
    assert np.isfinite(float(loss))
    # every adapter gradient must be non-trivially shaped and finite
    for g, a in zip(grads, adapters + [headw]):
        assert g.shape == a.shape
        assert bool(jnp.all(jnp.isfinite(g)))
    # L gradients are nonzero when R != 0 (grad flows through the product)
    assert any(float(jnp.max(jnp.abs(g))) > 0 for g in grads[:-1])


def test_qpeft_reg_head_mse():
    cfg, rank = MICRO, 4
    frozen, adapters, headw = qpeft_inputs(cfg, rank, head="reg", n_classes=1)
    t = tokens(cfg, b=3)
    y = jnp.asarray(RNG.normal(size=(3,)).astype("f4"))
    out = M.qpeft_cls_train(cfg, rank, "reg", 1)(*frozen, *adapters, headw, t, y)
    assert np.isfinite(float(out[0]))


def test_qlr_lm_fwd_equals_dense_when_exact():
    """qlr serving path with Q = W, L/R = 0 reproduces the dense LM logits."""
    cfg, rank = MICRO, 4
    ps = init_params(cfg)
    names = M.param_names(cfg)
    args = []
    for n, p in zip(names[:-1], ps[:-1]):
        if M.is_linear(n):
            din, dout = M.param_shape(n, cfg)
            args += [p, jnp.zeros((din, rank)), jnp.zeros((rank, dout))]
        else:
            args.append(p)
    args.append(ps[-1])
    t = tokens(cfg)
    (logits_q,) = M.qlr_lm_fwd(cfg, rank)(*args, t)
    (logits_d,) = M.lm_fwd(cfg)(*ps, t)
    np.testing.assert_allclose(np.asarray(logits_q), np.asarray(logits_d), rtol=1e-4, atol=1e-4)
