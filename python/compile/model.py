"""Layer-2 JAX model: LLaMA-style transformer LM + encoder classifier.

Pure-jax forward/backward graphs that call the Layer-1 Pallas kernels.
aot.py lowers each entry point once to HLO text; the rust coordinator
executes the artifacts via PJRT and owns everything else (quantization,
SRR decomposition, optimizers, gradient scaling, batching).

Parameter convention: every linear is stored as W with shape (in, out) and
applied as ``y = x @ W`` — the same orientation the paper's m x n weight
uses (x in R^m). Params travel as a flat list ordered by
:func:`param_names`; the manifest records that order for the rust side.

Two forward families:
  * ``lm_*`` / ``cls_*``      — full-precision weights (also used with
    reconstructed W_hat = Qdeq + L@R materialized on the rust side);
  * ``qpeft_*``               — frozen Qdeq plus trainable (L, R) adapters,
    computing y = x @ Qdeq + (x @ L) @ R (differentiated wrt adapters only);
  * ``qlr_lm_fwd``            — serving path where each linear runs the
    fused Pallas qlr_matmul kernel (inference artifact; not differentiated,
    as interpret-mode pallas_call is treated as a primal-only hot path).
"""

import jax
import jax.numpy as jnp

from .configs import LINEAR_KINDS, ModelCfg
from .kernels import attention as attention_pallas, qlr_matmul
from .kernels.ref import attention_ref

EPS = 1e-5

# ---------------------------------------------------------------------------
# parameter book-keeping
# ---------------------------------------------------------------------------


def layer_param_names(i: int):
    """Names of the i-th block's params, canonical order."""
    return [
        f"l{i}.ln1",
        f"l{i}.wq",
        f"l{i}.wk",
        f"l{i}.wv",
        f"l{i}.wo",
        f"l{i}.ln2",
        f"l{i}.gate",
        f"l{i}.up",
        f"l{i}.down",
    ]


def param_names(cfg: ModelCfg, head: str = "lm"):
    """Flat parameter order for the whole model. ``head``: 'lm'|'cls'|'reg'."""
    names = ["embed"]
    for i in range(cfg.n_layers):
        names += layer_param_names(i)
    names += ["norm_f", "head"]
    return names


def param_shape(name: str, cfg: ModelCfg, head: str = "lm", n_classes: int = 4):
    d, ff, v = cfg.d_model, cfg.d_ff, cfg.vocab
    if name == "embed":
        return (v, d)
    if name in ("norm_f",) or name.endswith(".ln1") or name.endswith(".ln2"):
        return (d,)
    if name == "head":
        return {"lm": (d, v), "cls": (d, n_classes), "reg": (d, 1)}[head]
    kind = name.split(".")[-1]
    return {
        "wq": (d, d),
        "wk": (d, d),
        "wv": (d, d),
        "wo": (d, d),
        "gate": (d, ff),
        "up": (d, ff),
        "down": (ff, d),
    }[kind]


def linear_names(cfg: ModelCfg):
    """All quantizable linear-layer names (the 7 projections per block)."""
    return [f"l{i}.{k}" for i in range(cfg.n_layers) for k in LINEAR_KINDS]


def is_linear(name: str) -> bool:
    return name.split(".")[-1] in LINEAR_KINDS


# ---------------------------------------------------------------------------
# building blocks
# ---------------------------------------------------------------------------


def rmsnorm(x, w):
    return x * w * jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + EPS)


def _heads(x, cfg: ModelCfg):
    b, t, _ = x.shape
    return x.reshape(b, t, cfg.n_heads, cfg.d_head).transpose(0, 2, 1, 3)


def _unheads(x):
    b, h, t, dh = x.shape
    return x.transpose(0, 2, 1, 3).reshape(b, t, h * dh)


def block_fwd(p, x, cfg: ModelCfg, causal: bool, apply, attn=attention_pallas):
    """One transformer block. ``apply(name, x2d) -> y2d`` runs a linear."""
    b, t, d = x.shape
    h = rmsnorm(x, p["ln1"])
    h2 = h.reshape(b * t, d)
    q = _heads(apply("wq", h2).reshape(b, t, d), cfg)
    k = _heads(apply("wk", h2).reshape(b, t, d), cfg)
    v = _heads(apply("wv", h2).reshape(b, t, d), cfg)
    a = attn(q, k, v, causal=causal)
    a2 = _unheads(a).reshape(b * t, d)
    x = x + apply("wo", a2).reshape(b, t, d)
    h = rmsnorm(x, p["ln2"])
    h2 = h.reshape(b * t, d)
    g = apply("gate", h2)
    u = apply("up", h2)
    m = (jax.nn.silu(g) * u)
    x = x + apply("down", m).reshape(b, t, d)
    return x


def _dense_apply(layer_params):
    def apply(name, x2d):
        return x2d @ layer_params[name]

    return apply


def trunk_fwd(params: dict, tokens, cfg: ModelCfg, causal: bool, apply_for_layer=None, attn=attention_pallas):
    """Embed + n_layers blocks + final norm. Returns (B, T, d)."""
    x = params["embed"][tokens]
    for i in range(cfg.n_layers):
        lp = {k.split(".", 1)[1]: v for k, v in params.items() if k.startswith(f"l{i}.")}
        apply = apply_for_layer(i) if apply_for_layer is not None else _dense_apply(lp)
        x = block_fwd(lp, x, cfg, causal, apply, attn=attn)
    return rmsnorm(x, params["norm_f"])


def to_dict(cfg: ModelCfg, flat, head: str = "lm"):
    return dict(zip(param_names(cfg, head), flat))


# ---------------------------------------------------------------------------
# LM entry points
# ---------------------------------------------------------------------------


def lm_logits(params: dict, tokens, cfg: ModelCfg, attn=attention_pallas):
    h = trunk_fwd(params, tokens, cfg, causal=True, attn=attn)
    return h @ params["head"]


def lm_fwd(cfg: ModelCfg):
    """(params..., tokens[B,T] i32) -> logits (B, T, vocab)."""

    def fn(*args):
        params = to_dict(cfg, args[:-1])
        return (lm_logits(params, args[-1], cfg),)

    return fn


def _nll_terms(logits, targets, mask):
    logp = jax.nn.log_softmax(logits, axis=-1)
    tok_ll = jnp.take_along_axis(logp, targets[..., None], axis=-1)[..., 0]
    return -(tok_ll * mask)


def lm_nll(cfg: ModelCfg):
    """(params..., tokens[B,T], mask[B,T]) -> (per_seq_nll (B,), per_seq_tokens (B,)).

    Next-token NLL over positions where mask[t+1] == 1. Perplexity and
    zero-shot option scoring both aggregate these on the rust side.
    """

    def fn(*args):
        params = to_dict(cfg, args[:-2])
        tokens, mask = args[-2], args[-1]
        logits = lm_logits(params, tokens[:, :-1], cfg)
        nll = _nll_terms(logits, tokens[:, 1:], mask[:, 1:])
        return (jnp.sum(nll, axis=-1), jnp.sum(mask[:, 1:], axis=-1))

    return fn


def lm_loss_value(params: dict, tokens, cfg: ModelCfg):
    # attention_ref: this graph is differentiated (see module docstring)
    logits = lm_logits(params, tokens[:, :-1], cfg, attn=attention_ref)
    nll = _nll_terms(logits, tokens[:, 1:], jnp.ones_like(tokens[:, 1:], jnp.float32))
    return jnp.mean(nll)


def lm_train(cfg: ModelCfg):
    """(params..., tokens[B,T]) -> (loss, grad_0, ..., grad_{P-1})."""
    n = len(param_names(cfg))

    def fn(*args):
        tokens = args[-1]

        def loss_fn(*params):
            return lm_loss_value(to_dict(cfg, params), tokens, cfg)

        loss, grads = jax.value_and_grad(loss_fn, argnums=tuple(range(n)))(*args[:-1])
        return (loss, *grads)

    return fn


# ---------------------------------------------------------------------------
# QPEFT: frozen Qdeq + trainable (L, R) adapters
# ---------------------------------------------------------------------------


def _qpeft_param_split(cfg: ModelCfg, head: str):
    """Frozen args then trainable args; returns (frozen_names, adapter_names)."""
    frozen = param_names(cfg, head)[:-1]  # all but head; linears carry Qdeq
    adapters = []
    for name in linear_names(cfg):
        adapters += [f"{name}.L", f"{name}.R"]
    adapters += ["head"]  # the head trains in full precision (QPEFT convention)
    return frozen, adapters


def qpeft_trunk(frozen: dict, adapters: dict, tokens, cfg: ModelCfg, causal: bool, attn=attention_ref):
    def apply_for_layer(i):
        def apply(name, x2d):
            full = f"l{i}.{name}"
            q = frozen[full]
            l, r = adapters[f"{full}.L"], adapters[f"{full}.R"]
            return x2d @ q + (x2d @ l) @ r

        return apply

    return trunk_fwd(frozen, tokens, cfg, causal, apply_for_layer, attn=attn)


def qpeft_lm_train(cfg: ModelCfg, rank: int):
    """(frozen..., adapters..., head, tokens) -> (loss, adapter_grads..., head_grad).

    Frozen args: embed, per-layer {ln1, Qdeq x4, ln2, Qdeq x3}, norm_f.
    Trainable: (L, R) per linear (rank ``rank``) + lm head.
    """
    frozen_names, adapter_names = _qpeft_param_split(cfg, "lm")
    nf, na = len(frozen_names), len(adapter_names)

    def fn(*args):
        frozen = dict(zip(frozen_names, args[:nf]))
        tokens = args[-1]

        def loss_fn(*train):
            ad = dict(zip(adapter_names, train))
            h = qpeft_trunk(frozen, ad, tokens[:, :-1], cfg, causal=True)
            logits = h @ ad["head"]
            nll = _nll_terms(
                logits, tokens[:, 1:], jnp.ones_like(tokens[:, 1:], jnp.float32)
            )
            return jnp.mean(nll)

        loss, grads = jax.value_and_grad(loss_fn, argnums=tuple(range(na)))(
            *args[nf : nf + na]
        )
        return (loss, *grads)

    return fn


def qpeft_lm_nll(cfg: ModelCfg, rank: int):
    """(frozen..., adapters..., head, tokens, mask) -> per-seq (nll, tokens) for eval."""
    frozen_names, adapter_names = _qpeft_param_split(cfg, "lm")
    nf, na = len(frozen_names), len(adapter_names)

    def fn(*args):
        frozen = dict(zip(frozen_names, args[:nf]))
        ad = dict(zip(adapter_names, args[nf : nf + na]))
        tokens, mask = args[-2], args[-1]
        h = qpeft_trunk(frozen, ad, tokens[:, :-1], cfg, causal=True)
        logits = h @ ad["head"]
        nll = _nll_terms(logits, tokens[:, 1:], mask[:, 1:])
        return (jnp.sum(nll, axis=-1), jnp.sum(mask[:, 1:], axis=-1))

    return fn


# ---------------------------------------------------------------------------
# classifier (GLUE-sim) entry points — bidirectional trunk + mean pool
# ---------------------------------------------------------------------------


def cls_logits(params: dict, tokens, cfg: ModelCfg, attn=attention_pallas):
    h = trunk_fwd(params, tokens, cfg, causal=False, attn=attn)
    return jnp.mean(h, axis=1) @ params["head"]


def _ce_loss(logits, labels):
    logp = jax.nn.log_softmax(logits, axis=-1)
    return -jnp.mean(jnp.take_along_axis(logp, labels[:, None], axis=-1))


def _mse_loss(pred, targets):
    return jnp.mean((pred[:, 0] - targets) ** 2)


def cls_fwd(cfg: ModelCfg, head: str, n_classes: int):
    def fn(*args):
        params = to_dict(cfg, args[:-1], head)
        return (cls_logits(params, args[-1], cfg),)

    return fn


def cls_train(cfg: ModelCfg, head: str, n_classes: int):
    """Full fine-tuning train step (the paper's Full FT / LoRA-16 baseline path)."""
    n = len(param_names(cfg, head))

    def fn(*args):
        tokens, labels = args[-2], args[-1]

        def loss_fn(*params):
            logits = cls_logits(to_dict(cfg, params, head), tokens, cfg, attn=attention_ref)
            if head == "reg":
                return _mse_loss(logits, labels)
            return _ce_loss(logits, labels)

        loss, grads = jax.value_and_grad(loss_fn, argnums=tuple(range(n)))(*args[:-2])
        return (loss, *grads)

    return fn


def qpeft_cls_train(cfg: ModelCfg, rank: int, head: str, n_classes: int):
    """(frozen..., adapters..., head, tokens, labels) -> (loss, grads...)."""
    frozen_names, adapter_names = _qpeft_param_split(cfg, head)
    nf, na = len(frozen_names), len(adapter_names)

    def fn(*args):
        frozen = dict(zip(frozen_names, args[:nf]))
        tokens, labels = args[-2], args[-1]

        def loss_fn(*train):
            ad = dict(zip(adapter_names, train))
            h = qpeft_trunk(frozen, ad, tokens, cfg, causal=False)
            logits = jnp.mean(h, axis=1) @ ad["head"]
            if head == "reg":
                return _mse_loss(logits, labels)
            return _ce_loss(logits, labels)

        loss, grads = jax.value_and_grad(loss_fn, argnums=tuple(range(na)))(
            *args[nf : nf + na]
        )
        return (loss, *grads)

    return fn


def qpeft_cls_fwd(cfg: ModelCfg, rank: int, head: str, n_classes: int):
    frozen_names, adapter_names = _qpeft_param_split(cfg, head)
    nf, na = len(frozen_names), len(adapter_names)

    def fn(*args):
        frozen = dict(zip(frozen_names, args[:nf]))
        ad = dict(zip(adapter_names, args[nf : nf + na]))
        tokens = args[-1]
        h = qpeft_trunk(frozen, ad, tokens, cfg, causal=False)
        return (jnp.mean(h, axis=1) @ ad["head"],)

    return fn


# ---------------------------------------------------------------------------
# serving path: fused Pallas QLR forward
# ---------------------------------------------------------------------------


def qlr_lm_fwd(cfg: ModelCfg, rank: int):
    """LM forward where every linear runs the fused Pallas qlr_matmul kernel.

    Args: embed, per-layer {ln1, (Qdeq, L, R) x4, ln2, (Qdeq, L, R) x3},
    norm_f, head, tokens. Inference-only artifact for the serving benches.
    """
    frozen_names = param_names(cfg)[:-1]

    def fn(*args):
        # args: frozen non-linear params interleaved with (q, l, r) triplets.
        it = iter(args[:-1])
        params = {}
        triplets = {}
        for name in frozen_names:
            if is_linear(name):
                triplets[name] = (next(it), next(it), next(it))
            else:
                params[name] = next(it)
        params["head"] = next(it)
        tokens = args[-1]

        def apply_for_layer(i):
            def apply(name, x2d):
                q, l, r = triplets[f"l{i}.{name}"]
                return qlr_matmul(x2d, q, l, r)

            return apply

        h = trunk_fwd(params, tokens, cfg, causal=True, apply_for_layer=apply_for_layer)
        return (h @ params["head"],)

    return fn
