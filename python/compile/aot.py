"""AOT compiler: lower every L2 entry point to HLO *text* + manifest.json.

Run once at build time (``make artifacts``); python never touches the
request path. HLO text — not ``.serialize()`` — is the interchange format:
jax >= 0.5 emits HloModuleProto with 64-bit instruction ids which
xla_extension 0.5.1 (the version behind the published ``xla`` 0.1.6 rust
crate) rejects (``proto.id() <= INT_MAX``); the HLO text parser reassigns
ids and round-trips cleanly (see /opt/xla-example/README.md).

The manifest records, for every artifact, the exact positional argument
list (name/shape/dtype) and output list, plus the model configs — the rust
side (rust/src/runtime/manifest.rs) is entirely manifest-driven.
"""

import argparse
import hashlib
import json
import os
import sys

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model as M
from .configs import (
    CLS_BATCH,
    CLS_CLASSES,
    CLS_SEQ,
    LM_BATCH,
    MODELS,
    QPEFT_RANKS,
)
from .kernels import attention, mxint_qdq, qlr_matmul

F32 = jnp.float32
I32 = jnp.int32


def spec(shape, dtype=F32):
    return jax.ShapeDtypeStruct(tuple(shape), dtype)


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


# ---------------------------------------------------------------------------
# argument-spec builders (mirror model.py's parameter orders)
# ---------------------------------------------------------------------------


def lm_param_args(cfg, head="lm", n_classes=CLS_CLASSES):
    return [
        (n, M.param_shape(n, cfg, head, n_classes), "f32")
        for n in M.param_names(cfg, head)
    ]


def qpeft_args(cfg, rank, head="lm", n_classes=CLS_CLASSES):
    frozen = [
        (n, M.param_shape(n, cfg, head, n_classes), "f32")
        for n in M.param_names(cfg, head)[:-1]
    ]
    adapters = []
    for name in M.linear_names(cfg):
        din, dout = M.param_shape(name, cfg)
        adapters.append((f"{name}.L", (din, rank), "f32"))
        adapters.append((f"{name}.R", (rank, dout), "f32"))
    adapters.append(("head", M.param_shape("head", cfg, head, n_classes), "f32"))
    return frozen + adapters


def qlr_args(cfg, rank):
    args = []
    for n in M.param_names(cfg)[:-1]:
        if M.is_linear(n):
            din, dout = M.param_shape(n, cfg)
            args.append((f"{n}.q", (din, dout), "f32"))
            args.append((f"{n}.L", (din, rank), "f32"))
            args.append((f"{n}.R", (rank, dout), "f32"))
        else:
            args.append((n, M.param_shape(n, cfg), "f32"))
    args.append(("head", M.param_shape("head", cfg), "f32"))
    return args


def build_catalog():
    """(name, fn, args) for every artifact. Kept in one place on purpose —
    this list is the compile-time contract with the rust side."""
    cat = []
    T = MODELS["tiny"]
    S = MODELS["small"]
    B = MODELS["base"]

    for cfg in (T, S, B):
        tok = [("tokens", (LM_BATCH, cfg.seq_len), "i32")]
        mask = [("mask", (LM_BATCH, cfg.seq_len), "f32")]
        cat.append((f"lm_fwd_{cfg.name}", M.lm_fwd(cfg), lm_param_args(cfg) + tok, cfg.name))
        cat.append((f"lm_nll_{cfg.name}", M.lm_nll(cfg), lm_param_args(cfg) + tok + mask, cfg.name))

    for cfg in (T, S):
        tok = [("tokens", (LM_BATCH, cfg.seq_len), "i32")]
        cat.append((f"lm_train_{cfg.name}", M.lm_train(cfg), lm_param_args(cfg) + tok, cfg.name))

    for rank in QPEFT_RANKS:
        tok = [("tokens", (LM_BATCH, T.seq_len), "i32")]
        mask = [("mask", (LM_BATCH, T.seq_len), "f32")]
        cat.append(
            (f"qpeft_lm_train_tiny_r{rank}", M.qpeft_lm_train(T, rank), qpeft_args(T, rank) + tok, "tiny")
        )
        cat.append(
            (f"qpeft_lm_nll_tiny_r{rank}", M.qpeft_lm_nll(T, rank), qpeft_args(T, rank) + tok + mask, "tiny")
        )

    # classifier (GLUE-sim) artifacts on a tiny trunk with CLS_SEQ inputs
    C = T  # same trunk; token inputs just use CLS_SEQ
    ctok = [("tokens", (CLS_BATCH, CLS_SEQ), "i32")]
    clab_i = [("labels", (CLS_BATCH,), "i32")]
    clab_f = [("labels", (CLS_BATCH,), "f32")]
    cat.append(("cls_fwd_tiny", M.cls_fwd(C, "cls", CLS_CLASSES), lm_param_args(C, "cls") + ctok, "tiny"))
    cat.append(("cls_train_tiny", M.cls_train(C, "cls", CLS_CLASSES), lm_param_args(C, "cls") + ctok + clab_i, "tiny"))
    cat.append(("cls_train_reg_tiny", M.cls_train(C, "reg", 1), lm_param_args(C, "reg") + ctok + clab_f, "tiny"))
    for rank in QPEFT_RANKS:
        cat.append(
            (f"qpeft_cls_train_tiny_r{rank}", M.qpeft_cls_train(C, rank, "cls", CLS_CLASSES),
             qpeft_args(C, rank, "cls") + ctok + clab_i, "tiny")
        )
        cat.append(
            (f"qpeft_cls_fwd_tiny_r{rank}", M.qpeft_cls_fwd(C, rank, "cls", CLS_CLASSES),
             qpeft_args(C, rank, "cls") + ctok, "tiny")
        )
        cat.append(
            (f"qpeft_cls_train_reg_tiny_r{rank}", M.qpeft_cls_train(C, rank, "reg", 1),
             qpeft_args(C, rank, "reg") + ctok + clab_f, "tiny")
        )
        cat.append(
            (f"qpeft_cls_fwd_reg_tiny_r{rank}", M.qpeft_cls_fwd(C, rank, "reg", 1),
             qpeft_args(C, rank, "reg") + ctok, "tiny")
        )

    # fused-Pallas serving path (perf benches)
    stok = [("tokens", (LM_BATCH, S.seq_len), "i32")]
    cat.append(("qlr_lm_fwd_small_r64", M.qlr_lm_fwd(S, 64), qlr_args(S, 64) + stok, "small"))

    # standalone kernel artifacts: rust-side parity tests + kernel benches
    for bits in (2, 3, 4):
        cat.append(
            (f"kernel_mxint{bits}", lambda w, b=bits: (mxint_qdq(w, b),),
             [("w", (128, 256), "f32")], None)
        )
    cat.append(
        ("kernel_qlr", lambda x, q, l, r: (qlr_matmul(x, q, l, r),),
         [("x", (64, 256), "f32"), ("q", (256, 256), "f32"),
          ("l", (256, 64), "f32"), ("r", (64, 256), "f32")], None)
    )
    cat.append(
        ("kernel_attn", lambda q, k, v: (attention(q, k, v, causal=True),),
         [("q", (2, 4, 64, 32), "f32"), ("k", (2, 4, 64, 32), "f32"),
          ("v", (2, 4, 64, 32), "f32")], None)
    )
    return cat


DTYPES = {"f32": F32, "i32": I32}


def lower_one(name, fn, args, outdir):
    specs = [spec(sh, DTYPES[dt]) for (_, sh, dt) in args]
    lowered = jax.jit(fn).lower(*specs)
    text = to_hlo_text(lowered)
    path = os.path.join(outdir, f"{name}.hlo.txt")
    with open(path, "w") as f:
        f.write(text)
    outs = jax.eval_shape(fn, *specs)
    out_meta = [
        {"shape": list(o.shape), "dtype": "f32" if o.dtype == jnp.float32 else "i32"}
        for o in outs
    ]
    return {
        "name": name,
        "file": f"{name}.hlo.txt",
        "args": [{"name": n, "shape": list(sh), "dtype": dt} for (n, sh, dt) in args],
        "outputs": out_meta,
    }


def source_fingerprint():
    h = hashlib.sha256()
    root = os.path.dirname(os.path.abspath(__file__))
    for dirpath, _, files in sorted(os.walk(root)):
        for f in sorted(files):
            if f.endswith(".py"):
                with open(os.path.join(dirpath, f), "rb") as fh:
                    h.update(fh.read())
    return h.hexdigest()


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts/manifest.json",
                    help="manifest path; artifacts are written beside it")
    ap.add_argument("--only", default=None, help="comma-separated artifact names")
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()

    outdir = os.path.dirname(os.path.abspath(args.out))
    os.makedirs(outdir, exist_ok=True)
    manifest_path = os.path.abspath(args.out)

    fp = source_fingerprint()
    if not args.force and not args.only and os.path.exists(manifest_path):
        try:
            with open(manifest_path) as f:
                old = json.load(f)
            if old.get("fingerprint") == fp and all(
                os.path.exists(os.path.join(outdir, a["file"])) for a in old["artifacts"]
            ):
                print(f"artifacts up to date ({len(old['artifacts'])} modules), skipping")
                return
        except (json.JSONDecodeError, KeyError):
            pass

    catalog = build_catalog()
    if args.only:
        names = set(args.only.split(","))
        catalog = [c for c in catalog if c[0] in names]

    entries = []
    for i, (name, fn, aspecs, _model) in enumerate(catalog):
        print(f"[{i + 1}/{len(catalog)}] lowering {name} ...", flush=True)
        entries.append(lower_one(name, fn, aspecs, outdir))

    manifest = {
        "version": 1,
        "fingerprint": fp,
        "models": {n: c.to_dict() for n, c in MODELS.items()},
        "constants": {
            "lm_batch": LM_BATCH,
            "cls_batch": CLS_BATCH,
            "cls_seq": CLS_SEQ,
            "cls_classes": CLS_CLASSES,
            "qpeft_ranks": list(QPEFT_RANKS),
        },
        "param_order": {
            n: M.param_names(c) for n, c in MODELS.items()
        },
        "linear_names": {n: M.linear_names(c) for n, c in MODELS.items()},
        "artifacts": entries,
    }
    with open(manifest_path, "w") as f:
        json.dump(manifest, f, indent=1)
    print(f"wrote {len(entries)} artifacts + manifest to {outdir}")


if __name__ == "__main__":
    sys.exit(main())
