"""Pallas kernel: tiled causal attention with online softmax.

Flash-attention restructured for TPU: the (block_q) query tile and the
running (max, sum, acc) statistics live in VMEM across an inner fori_loop
over key/value tiles, so the (T, T) score matrix never materializes. The
grid is (B*H, T/block_q); BlockSpec streams the per-head K/V panels.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _attn_kernel(q_ref, k_ref, v_ref, o_ref, *, block_q, block_k, causal, scale):
    qi = pl.program_id(1)
    q = q_ref[0]  # (block_q, dh)
    k = k_ref[0]  # (T, dh)
    v = v_ref[0]  # (T, dh)
    t = k.shape[0]
    dh = q.shape[-1]
    nkb = t // block_k
    row = qi * block_q + jax.lax.iota(jnp.int32, block_q)

    def body(kb, carry):
        m_i, l_i, acc = carry
        ks = jax.lax.dynamic_slice_in_dim(k, kb * block_k, block_k, axis=0)
        vs = jax.lax.dynamic_slice_in_dim(v, kb * block_k, block_k, axis=0)
        s = jnp.dot(q, ks.T, preferred_element_type=jnp.float32) * scale
        if causal:
            col = kb * block_k + jax.lax.iota(jnp.int32, block_k)
            s = jnp.where(row[:, None] >= col[None, :], s, NEG_INF)
        m_new = jnp.maximum(m_i, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[:, None])
        alpha = jnp.exp(m_i - m_new)
        l_new = l_i * alpha + jnp.sum(p, axis=-1)
        acc = acc * alpha[:, None] + jnp.dot(p, vs, preferred_element_type=jnp.float32)
        return m_new, l_new, acc

    m0 = jnp.full((block_q,), NEG_INF, jnp.float32)
    l0 = jnp.zeros((block_q,), jnp.float32)
    a0 = jnp.zeros((block_q, dh), jnp.float32)
    # With a causal mask, key tiles strictly above the diagonal contribute
    # nothing; bound the loop at the query tile's last row.
    upper = (qi + 1) * block_q // block_k if causal else nkb
    m_i, l_i, acc = jax.lax.fori_loop(0, upper if causal else nkb, body, (m0, l0, a0))
    o_ref[0] = (acc / jnp.maximum(l_i, 1e-30)[:, None]).astype(o_ref.dtype)


def attention(q, k, v, causal: bool = True, block_q: int = 32, block_k: int = 32):
    """Causal attention over (B, H, T, Dh) tensors via a tiled Pallas kernel."""
    b, h, t, dh = q.shape
    bq = min(block_q, t)
    while t % bq != 0:
        bq -= 1
    bk = min(block_k, t)
    while t % bk != 0:
        bk -= 1
    if causal and bq % bk != 0:
        bk = bq  # keep the causal loop bound exact
    qf = q.reshape(b * h, t, dh)
    kf = k.reshape(b * h, t, dh)
    vf = v.reshape(b * h, t, dh)
    kernel = functools.partial(
        _attn_kernel, block_q=bq, block_k=bk, causal=causal, scale=1.0 / (dh**0.5)
    )
    out = pl.pallas_call(
        kernel,
        grid=(b * h, t // bq),
        in_specs=[
            pl.BlockSpec((1, bq, dh), lambda bh, i: (bh, i, 0)),
            pl.BlockSpec((1, t, dh), lambda bh, i: (bh, 0, 0)),
            pl.BlockSpec((1, t, dh), lambda bh, i: (bh, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, dh), lambda bh, i: (bh, i, 0)),
        out_shape=jax.ShapeDtypeStruct((b * h, t, dh), jnp.float32),
        interpret=True,
    )(qf, kf, vf)
    return out.reshape(b, h, t, dh)
