"""Pallas kernel: fused quantized + low-rank matmul y = x @ Q + (x @ L) @ R.

This is the serving hot path of every QER-reconstructed layer
(W_hat = Q + LR). The GPU formulation runs two GEMMs plus an epilogue; on
TPU we restructure it as a single kernel over a (M/bm, N/bn, K/bk) grid:

  o[i,j] += x[i,k] @ Q[k,j] + (x[i,k] @ L[k,:]) @ R[:,j]

Both terms feed the MXU; the rank-r factors are tiny (r <= 64), so the
L k-tile (bk, r) and R j-tile (r, bn) stay VMEM-resident while Q tiles
stream HBM->VMEM. The identity (xL)R = sum_k (x[:,k] L[k,:]) R makes the
correction accumulate in the same k-loop as the dense term — no second
pass over x and no (M, r) intermediate in HBM.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _qlr_kernel(x_ref, q_ref, l_ref, r_ref, o_ref):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    x = x_ref[...]
    acc = jnp.dot(x, q_ref[...], preferred_element_type=jnp.float32)
    xl = jnp.dot(x, l_ref[...], preferred_element_type=jnp.float32)
    acc = acc + jnp.dot(xl, r_ref[...], preferred_element_type=jnp.float32)
    o_ref[...] += acc


def _tile(dim: int, want: int) -> int:
    t = min(want, dim)
    while dim % t != 0:
        t -= 1
    return t


def qlr_matmul(x, qdeq, l, r, block_m: int = 64, block_n: int = 128, block_k: int = 128):
    """y = x @ qdeq + (x @ l) @ r, fused. x: (M, K), qdeq: (K, N), l: (K, r), r: (r, N)."""
    m, k = x.shape
    k2, n = qdeq.shape
    assert k == k2 and l.shape[0] == k and l.shape[1] == r.shape[0] and r.shape[1] == n
    bm, bn, bk = _tile(m, block_m), _tile(n, block_n), _tile(k, block_k)
    rr = l.shape[1]
    return pl.pallas_call(
        _qlr_kernel,
        grid=(m // bm, n // bn, k // bk),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
            pl.BlockSpec((bk, rr), lambda i, j, kk: (kk, 0)),
            pl.BlockSpec((rr, bn), lambda i, j, kk: (0, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=True,
    )(x, qdeq, l, r)
