"""Pure-jnp oracles for the Pallas kernels.

These are the correctness ground truth: each Pallas kernel must match its
oracle to float32 tolerance under pytest + hypothesis sweeps
(python/tests/test_kernel.py). They are also the reference semantics the
rust-side implementations (rust/src/quant/mxint.rs etc.) mirror.
"""

import jax.numpy as jnp


def mxint_qdq_ref(w, bits: int, block: int = 32):
    """MXINT quantize->dequantize (reference).

    Block-wise shared power-of-two exponent along the last axis with a
    signed ``bits``-bit mantissa, following Darvish Rouhani et al. (2023):

      E      = floor(log2(max|w_block|))
      scale  = 2^(E - bits + 2)
      q      = clip(round(w / scale), -(2^(bits-1) - 1), 2^(bits-1) - 1)
      deq    = q * scale

    The shared exponent costs 8 bits per ``block`` elements, so the
    effective bitwidth is ``bits + 8/block`` (3.25 for 3-bit, block 32).
    All-zero blocks dequantize to exactly zero. Round-half-to-even is used
    (jnp.round), matching the rust implementation.
    """
    m, n = w.shape
    assert n % block == 0, f"n={n} not divisible by block={block}"
    wb = w.reshape(m, n // block, block)
    maxabs = jnp.max(jnp.abs(wb), axis=-1, keepdims=True)
    qmax = float(2 ** (bits - 1) - 1)
    e = jnp.floor(jnp.log2(jnp.where(maxabs > 0, maxabs, 1.0)))
    scale = jnp.exp2(e - (bits - 2))
    q = jnp.clip(jnp.round(wb / scale), -qmax, qmax)
    deq = jnp.where(maxabs > 0, q * scale, 0.0)
    return deq.reshape(m, n).astype(w.dtype)


def qlr_matmul_ref(x, qdeq, l, r):
    """Fused quantized + low-rank layer output: y = x @ Qdeq + (x @ L) @ R."""
    return x @ qdeq + (x @ l) @ r


def attention_ref(q, k, v, causal: bool = True):
    """Multi-head scaled dot-product attention (reference).

    q, k, v: (B, H, T, Dh). Returns (B, H, T, Dh).
    """
    dh = q.shape[-1]
    scores = jnp.einsum("bhqd,bhkd->bhqk", q, k) / jnp.sqrt(float(dh))
    if causal:
        tq, tk = q.shape[2], k.shape[2]
        mask = jnp.tril(jnp.ones((tq, tk), dtype=bool))
        scores = jnp.where(mask[None, None], scores, -1e30)
    probs = jnp.exp(scores - jnp.max(scores, axis=-1, keepdims=True))
    probs = probs / jnp.sum(probs, axis=-1, keepdims=True)
    return jnp.einsum("bhqk,bhkd->bhqd", probs, v)
