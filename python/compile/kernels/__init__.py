"""Layer-1 Pallas kernels (build-time only).

Every kernel here is lowered with ``interpret=True`` so that the resulting
HLO contains plain XLA ops runnable by the CPU PJRT client (xla_extension
0.5.1). Real-TPU lowering would emit Mosaic custom-calls which the CPU
plugin cannot execute; TPU performance is therefore estimated analytically
(see DESIGN.md section 8) while numerics are validated here against the
pure-jnp oracles in :mod:`ref`.
"""

from . import ref  # noqa: F401
from .mxint import mxint_qdq  # noqa: F401
from .qlr_matmul import qlr_matmul  # noqa: F401
from .attention import attention  # noqa: F401
