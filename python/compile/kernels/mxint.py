"""Pallas kernel: block-wise MXINT quantize -> dequantize.

TPU mapping (see DESIGN.md section "Hardware adaptation"): the MX block of
32 elements aligns with a quarter VPU lane row; each grid step owns an
(bm, n) row-tile held in VMEM, computes per-block shared exponents with a
single max-reduce, and applies the power-of-two scaling entirely on the
VPU — no gathers, no data-dependent control flow. The HBM<->VMEM schedule
is expressed with a 1-D grid over row tiles (BlockSpec), which on a real
TPU double-buffers row tiles against the elementwise work.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK = 32


def _mxint_kernel(w_ref, o_ref, *, bits: int, block: int):
    w = w_ref[...]
    bm, n = w.shape
    wb = w.reshape(bm, n // block, block)
    maxabs = jnp.max(jnp.abs(wb), axis=-1, keepdims=True)
    qmax = float(2 ** (bits - 1) - 1)
    e = jnp.floor(jnp.log2(jnp.where(maxabs > 0, maxabs, 1.0)))
    scale = jnp.exp2(e - (bits - 2))
    q = jnp.clip(jnp.round(wb / scale), -qmax, qmax)
    deq = jnp.where(maxabs > 0, q * scale, 0.0)
    o_ref[...] = deq.reshape(bm, n).astype(o_ref.dtype)


def mxint_qdq(w, bits: int, block: int = BLOCK, block_m: int = 8):
    """Quantize ``w`` (m, n) to MXINT-``bits`` and dequantize back to f32.

    ``block`` is the MX shared-exponent block along the last axis;
    ``block_m`` is the row-tile height of the Pallas grid.
    """
    m, n = w.shape
    assert n % block == 0, f"n={n} % block={block} != 0"
    bm = min(block_m, m)
    while m % bm != 0:  # shrink to a divisor so the grid tiles exactly
        bm -= 1
    kernel = functools.partial(_mxint_kernel, bits=bits, block=block)
    return pl.pallas_call(
        kernel,
        grid=(m // bm,),
        in_specs=[pl.BlockSpec((bm, n), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((bm, n), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=True,
    )(w)
