"""Model / artifact configurations shared by model.py and aot.py.

These are the single source of truth for shapes; aot.py serializes them
into artifacts/manifest.json, which the rust side parses at runtime
(rust/src/model/config.rs) — nothing is hard-coded twice.

The three LM sizes stand in for the paper's six checkpoints (TinyLlama ->
LLaMA-3.1 70B): what SRR depends on is the spectral structure of SW, which
the rust-side synthetic weight generator reproduces per projection type.
"""

from dataclasses import dataclass, asdict


@dataclass(frozen=True)
class ModelCfg:
    name: str
    vocab: int
    d_model: int
    n_heads: int
    n_layers: int
    d_ff: int
    seq_len: int

    @property
    def d_head(self) -> int:
        return self.d_model // self.n_heads

    def to_dict(self):
        return asdict(self)


TINY = ModelCfg("tiny", vocab=256, d_model=128, n_heads=4, n_layers=2, d_ff=512, seq_len=64)
SMALL = ModelCfg("small", vocab=1024, d_model=256, n_heads=8, n_layers=4, d_ff=1024, seq_len=128)
BASE = ModelCfg("base", vocab=2048, d_model=384, n_heads=8, n_layers=6, d_ff=1536, seq_len=128)

MODELS = {c.name: c for c in (TINY, SMALL, BASE)}

# Batch sizes baked into the AOT artifacts (PJRT executables have static shapes).
LM_BATCH = 8
CLS_BATCH = 16
CLS_SEQ = 32
CLS_CLASSES = 4  # synthetic GLUE-sim tasks use <= 4 classes; extras are unused logits

# Adapter ranks for which QPEFT train-step artifacts are generated:
# r=8 for the 4/3-bit GLUE + CLM settings, r=64 for the 2-bit + GSM settings (paper A.3).
QPEFT_RANKS = (8, 64)

# The seven projection types of a LLaMA-style block, in canonical order.
# Matches the paper's Fig. 5 taxonomy (Query/Key/Value/Output/Gate/Up/Down).
LINEAR_KINDS = ("wq", "wk", "wv", "wo", "gate", "up", "down")
